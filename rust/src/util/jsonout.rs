//! Minimal JSON: a writer for reports and a small parser sufficient for
//! `artifacts/manifest.json` (objects, arrays, numbers, strings, bools).
//! No serde is available offline; this is deliberately tiny and strict
//! enough for our own well-formed files.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0);
        s
    }

    fn write(&self, out: &mut String, indent: usize) {
        let pad = "  ".repeat(indent);
        let pad1 = "  ".repeat(indent + 1);
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => {
                let _ = write!(out, "{b}");
            }
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => {
                let _ = write!(out, "\"{}\"", escape(s));
            }
            Json::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push_str("[\n");
                for (i, v) in a.iter().enumerate() {
                    out.push_str(&pad1);
                    v.write(out, indent + 1);
                    if i + 1 < a.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                out.push_str(&pad);
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    let _ = write!(out, "{pad1}\"{}\": ", escape(k));
                    v.write(out, indent + 1);
                    if i + 1 < m.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                out.push_str(&pad);
                out.push('}');
            }
        }
    }
}

fn escape(s: &str) -> String {
    s.chars()
        .flat_map(|c| match c {
            '"' => "\\\"".chars().collect::<Vec<_>>(),
            '\\' => "\\\\".chars().collect(),
            '\n' => "\\n".chars().collect(),
            '\t' => "\\t".chars().collect(),
            c => vec![c],
        })
        .collect()
}

/// Convenience constructors.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}
pub fn num(n: f64) -> Json {
    Json::Num(n)
}
pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

/// Parse a JSON document. Strict enough for our own emitted files.
pub fn parse(text: &str) -> Result<Json, String> {
    let mut p = Parser {
        b: text.as_bytes(),
        i: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        return Err(format!("trailing data at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && (self.b[self.i] as char).is_whitespace() {
            self.i += 1;
        }
    }
    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }
    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|t| t.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Advance over one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|e| e.to_string())?;
                    let ch = rest.chars().next().unwrap();
                    out.push(ch);
                    self.i += ch.len_utf8();
                }
                None => return Err("unterminated string".into()),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                other => return Err(format!("expected , or }} got {other:?}")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                other => return Err(format!("expected , or ] got {other:?}")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_manifest_like() {
        let text = r#"{
  "block_rows": 4096,
  "cols": 8,
  "compute": [
    {"k": 1, "file": "compute_k1.hlo.txt"},
    {"k": 4, "file": "compute_k4.hlo.txt"}
  ],
  "aggregate": {"file": "aggregate.hlo.txt"}
}"#;
        let v = parse(text).unwrap();
        assert_eq!(v.get("block_rows").unwrap().as_usize(), Some(4096));
        let compute = v.get("compute").unwrap().as_arr().unwrap();
        assert_eq!(compute.len(), 2);
        assert_eq!(compute[1].get("k").unwrap().as_usize(), Some(4));
        assert_eq!(
            compute[0].get("file").unwrap().as_str(),
            Some("compute_k1.hlo.txt")
        );
    }

    #[test]
    fn parse_emit_roundtrip() {
        let v = obj(vec![
            ("name", s("scenario 1")),
            ("jobs", Json::Arr(vec![num(1.0), num(2.5)])),
            ("nested", obj(vec![("ok", Json::Bool(true))])),
        ]);
        let text = v.to_string_pretty();
        let back = parse(&text).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{unquoted: 1}").is_err());
        assert!(parse("[1, 2,]").is_err());
        assert!(parse("").is_err());
        assert!(parse("{} extra").is_err());
    }

    #[test]
    fn string_escapes() {
        let v = parse(r#""a\"b\\c\nd""#).unwrap();
        assert_eq!(v.as_str(), Some("a\"b\\c\nd"));
    }
}
