//! Deterministic PRNG (xoshiro256**) + the samplers the workload layer
//! needs: uniform, exponential (Poisson inter-arrival), lognormal, normal.
//!
//! Every experiment in the repo is seeded, so runs are reproducible
//! byte-for-byte across machines.

/// splitmix64 — used to seed xoshiro from a single u64.
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256** 1.0 — public-domain generator by Blackman & Vigna.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream (for per-user / per-job sub-streams).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Rejection-free Lemire-style; bias is negligible for our n << 2^64.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Exponential with rate `lambda` (mean 1/lambda) — Poisson gaps.
    pub fn exp(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        let u = 1.0 - self.f64(); // (0,1]
        -u.ln() / lambda
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Lognormal with parameters (mu, sigma) of the underlying normal.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Pareto with shape `alpha` and scale (minimum) `xm` — the
    /// heavy-tailed job-size law of the `heavytail` stress scenario.
    /// Inverse-CDF sampling: `xm * u^(-1/alpha)` with `u ∈ (0, 1]`.
    pub fn pareto(&mut self, alpha: f64, xm: f64) -> f64 {
        debug_assert!(alpha > 0.0 && xm > 0.0);
        let u = 1.0 - self.f64(); // (0,1]
        xm * u.powf(-1.0 / alpha)
    }

    /// Shuffle a slice in place (Fisher–Yates).
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn exp_mean_close() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.exp(0.5)).sum::<f64>() / n as f64;
        assert!((mean - 2.0).abs() < 0.05, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn lognormal_positive() {
        let mut r = Rng::new(17);
        for _ in 0..1000 {
            assert!(r.lognormal(0.0, 1.5) > 0.0);
        }
    }

    #[test]
    fn pareto_scale_and_median() {
        let mut r = Rng::new(19);
        let (alpha, xm) = (1.5, 2.0);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.pareto(alpha, xm)).collect();
        assert!(xs.iter().all(|&x| x >= xm), "Pareto support starts at xm");
        // Median = xm * 2^(1/alpha).
        let mut sorted = xs;
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let med = sorted[n / 2];
        let expect = xm * 2f64.powf(1.0 / alpha);
        assert!((med / expect - 1.0).abs() < 0.05, "median {med} vs {expect}");
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(5);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(23);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }
}
