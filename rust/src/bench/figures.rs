//! Figure regeneration (§3.2 Figs. 3–4, §5.2.2 Figs. 5–6, §5.3.1 Fig. 7).
//!
//! Every figure grid runs as cells on the [`crate::sweep`] engine; cell
//! order (and therefore output order) is fixed by construction, so
//! parallel sweeps emit byte-identical CSVs.

use std::collections::HashMap;

use super::{paper_cells, run_one_in};
use crate::config::Config;
use crate::core::job::{CostProfile, JobSpec};
use crate::metrics::cdf::{write_cdfs, CdfSeries};
use crate::metrics::fairness::user_violations_vs_ujf;
use crate::partition::SchemeKind;
use crate::sched::PolicyKind;
use crate::sweep::Sweep;
use crate::util::csvout::Csv;
use crate::workload::registry::builtin_workload;
use crate::workload::{scenarios, UserClass, Workload};

// ---------------------------------------------------------------------------
// Fig. 3 — task skew vs runtime partitioning (single job Gantt)
// ---------------------------------------------------------------------------

#[derive(Clone, Debug)]
pub struct Fig3Result {
    /// (scheme label, job completion seconds, task spans (core, start, end)).
    pub runs: Vec<(String, f64, Vec<(usize, f64, f64)>)>,
}

/// Tune `maxPartitionBytes`/advisory size so the dataset splits into
/// exactly one partition per core — the paper's §5.1 empirical tuning and
/// the premise of Figs. 3–4 ("data divided equally among available
/// cores", one task per core).
fn tuned(base: &Config) -> Config {
    let mut cfg = base.clone();
    cfg.max_partition_bytes = crate::workload::DATASET_BYTES / base.cores as u64;
    cfg.advisory_partition_bytes = cfg.max_partition_bytes;
    cfg
}

/// One job with a 5× hot partition under default one-per-core
/// partitioning; compare default vs ATR partitioning completion time.
pub fn fig3(base: &Config, sweep: &Sweep) -> Fig3Result {
    let base = &tuned(base);
    let skew = CostProfile::skewed(1.0 / base.cores as f64, 5.0);
    let job = JobSpec::three_phase(
        1,
        "skewed",
        0,
        crate::workload::SHORT_COMPUTE_SLOT,
        crate::workload::DATASET_BYTES,
        16,
        Some(skew),
    );
    let cells: Vec<Config> = [SchemeKind::Size, SchemeKind::Runtime]
        .into_iter()
        .map(|scheme| {
            let mut cfg = base.clone().with_scheme(scheme).with_policy(PolicyKind::Fifo);
            cfg.log_tasks = true;
            cfg
        })
        .collect();
    let runs = sweep.run(&cells, |ctx, cfg| {
        let rep = ctx.simulate(cfg, vec![job.clone()]);
        let spans = rep
            .task_log
            .iter()
            .map(|t| (t.core, crate::us_to_s(t.started), crate::us_to_s(t.finished)))
            .collect();
        (cfg.label(), rep.completed[0].response_time(), spans)
    });
    Fig3Result { runs }
}

// ---------------------------------------------------------------------------
// Fig. 4 — priority inversion
// ---------------------------------------------------------------------------

#[derive(Clone, Debug)]
pub struct Fig4Result {
    /// (scheme label, high-priority job RT, low-priority job RT).
    pub runs: Vec<(String, f64, f64)>,
}

/// A long low-priority (blue) job arrives just before a short
/// high-priority (red) job. Without runtime partitioning the red job
/// waits for blue's long tasks; with it, cores free after ~ATR.
pub fn fig4(base: &Config, sweep: &Sweep) -> Fig4Result {
    let base = &tuned(base);
    // Blue: user 1, long job; Red: user 2, short job arriving 0.2 s later.
    // Under UWFQ the red job has the earlier virtual deadline.
    let blue = JobSpec::three_phase(
        1,
        "blue-long",
        0,
        8.0 * base.cores as f64, // 8 s per core of work
        crate::workload::DATASET_BYTES,
        64,
        None,
    );
    let red = scenarios::micro_job(2, "tiny", 0.2, None);
    let cells: Vec<Config> = [SchemeKind::Size, SchemeKind::Runtime]
        .into_iter()
        .map(|scheme| base.clone().with_scheme(scheme).with_policy(PolicyKind::Uwfq))
        .collect();
    let runs = sweep.run(&cells, |ctx, cfg| {
        let rep = ctx.simulate(cfg, vec![blue.clone(), red.clone()]);
        let rt_of = |name: &str| {
            rep.completed
                .iter()
                .find(|c| &*c.name == name)
                .map(|c| c.response_time())
                .unwrap_or(f64::NAN)
        };
        (cfg.label(), rt_of("tiny"), rt_of("blue-long"))
    });
    Fig4Result { runs }
}

// ---------------------------------------------------------------------------
// Fig. 5 / Fig. 6 — CDFs
// ---------------------------------------------------------------------------

/// Fig. 5: empirical CDFs of infrequent-user response times (scenario 1)
/// across the four schedulers (one cell per scheduler).
pub fn fig5(seed: u64, base: &Config, sweep: &Sweep) -> Vec<CdfSeries> {
    let w = builtin_workload("scenario1", seed);
    let cells: Vec<(PolicyKind, Config)> = PolicyKind::PAPER
        .iter()
        .map(|&p| (p, base.clone().with_policy(p)))
        .collect();
    sweep.run(&cells, |ctx, (p, cfg)| {
        let m = run_one_in(ctx, cfg, &w);
        CdfSeries::from_samples(p.name(), &m.rts_of_class(UserClass::Infrequent))
    })
}

/// Fig. 6: empirical CDFs of job *completion times* in scenario 2 — shows
/// UWFQ finishing jobs gradually vs batched completion under Fair/UJF.
pub fn fig6(seed: u64, base: &Config, sweep: &Sweep) -> Vec<CdfSeries> {
    let w = builtin_workload("scenario2", seed);
    let cells: Vec<(PolicyKind, Config)> = PolicyKind::PAPER
        .iter()
        .map(|&p| (p, base.clone().with_policy(p)))
        .collect();
    sweep.run(&cells, |ctx, (p, cfg)| {
        let m = run_one_in(ctx, cfg, &w);
        CdfSeries::from_samples(p.name(), &m.finish_times())
    })
}

// ---------------------------------------------------------------------------
// Fig. 7 — per-user proportional deadline violations (macro)
// ---------------------------------------------------------------------------

/// Per-user proportional violation of mean RT vs the UJF reference, for
/// CFQ/UWFQ/Fair under both partitioning schemes — one 8-cell grid (each
/// scheme group: UJF reference first, then the compared policies).
pub fn fig7(workload: &Workload, base: &Config, sweep: &Sweep) -> HashMap<String, Vec<(u32, f64)>> {
    let schemes = super::TABLE_SCHEMES;
    let cells: Vec<Config> = schemes
        .iter()
        .flat_map(|&s| paper_cells(&base.clone().with_scheme(s)))
        .collect();
    let metrics = sweep.run(&cells, |ctx, cfg| run_one_in(ctx, cfg, workload));

    let per_scheme = cells.len() / schemes.len();
    let mut out = HashMap::new();
    for group in metrics.chunks(per_scheme) {
        let ujf = &group[0];
        for m in &group[1..] {
            out.insert(m.label.clone(), user_violations_vs_ujf(m, ujf));
        }
    }
    out
}

// ---------------------------------------------------------------------------
// CSV emitters
// ---------------------------------------------------------------------------

pub fn write_fig3_csv(dir: &str, f: &Fig3Result) -> std::io::Result<()> {
    let mut csv = Csv::create(
        format!("{dir}/fig3_gantt.csv"),
        &["scheme", "core", "start_s", "end_s"],
    )?;
    for (label, _, spans) in &f.runs {
        for (core, s, e) in spans {
            csv.row(&[
                label.clone(),
                core.to_string(),
                format!("{s:.4}"),
                format!("{e:.4}"),
            ])?;
        }
    }
    csv.finish()?;
    let mut csv = Csv::create(
        format!("{dir}/fig3_completion.csv"),
        &["scheme", "completion_s"],
    )?;
    for (label, rt, _) in &f.runs {
        csv.row(&[label.clone(), format!("{rt:.4}")])?;
    }
    csv.finish()
}

pub fn write_fig4_csv(dir: &str, f: &Fig4Result) -> std::io::Result<()> {
    let mut csv = Csv::create(
        format!("{dir}/fig4_inversion.csv"),
        &["scheme", "highprio_rt_s", "lowprio_rt_s"],
    )?;
    for (label, hi, lo) in &f.runs {
        csv.row(&[label.clone(), format!("{hi:.4}"), format!("{lo:.4}")])?;
    }
    csv.finish()
}

pub fn write_fig5_csv(dir: &str, series: &[CdfSeries]) -> std::io::Result<()> {
    write_cdfs(&format!("{dir}/fig5_infrequent_cdf.csv"), series)
}

pub fn write_fig6_csv(dir: &str, series: &[CdfSeries]) -> std::io::Result<()> {
    write_cdfs(&format!("{dir}/fig6_completion_cdf.csv"), series)
}

pub fn write_fig7_csv(
    dir: &str,
    data: &HashMap<String, Vec<(u32, f64)>>,
) -> std::io::Result<()> {
    let mut csv = Csv::create(
        format!("{dir}/fig7_user_violations.csv"),
        &["scheduler", "user", "proportional_violation"],
    )?;
    let mut labels: Vec<&String> = data.keys().collect();
    labels.sort();
    for label in labels {
        for (user, r) in &data[label] {
            csv.row(&[label.clone(), user.to_string(), format!("{r:.4}")])?;
        }
    }
    csv.finish()
}

/// Default macro workload for Fig. 7 / Table 2 — the `gtrace` registry
/// entry with paper-default params.
pub fn default_macro_workload(seed: u64) -> Workload {
    builtin_workload("gtrace", seed)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base() -> Config {
        Config::default().with_cores(8)
    }

    #[test]
    fn fig3_runtime_partitioning_beats_skew() {
        let f = fig3(&base(), &Sweep::seq());
        assert_eq!(f.runs.len(), 2);
        let default_rt = f.runs[0].1;
        let runtime_rt = f.runs[1].1;
        assert!(
            runtime_rt < default_rt * 0.8,
            "expected speedup: default {default_rt}, runtime {runtime_rt}"
        );
        // Gantt spans recorded for both runs.
        assert!(f.runs.iter().all(|(_, _, s)| !s.is_empty()));
    }

    #[test]
    fn fig4_inversion_mitigated() {
        let f = fig4(&base(), &Sweep::seq());
        let default_hi = f.runs[0].1;
        let runtime_hi = f.runs[1].1;
        assert!(
            runtime_hi < default_hi,
            "high-prio RT should improve with -P: {runtime_hi} vs {default_hi}"
        );
    }

    #[test]
    fn fig6_series_cover_all_schedulers() {
        let mut cfg = base();
        cfg.seed = 3;
        let series = fig6(3, &cfg, &Sweep::seq());
        assert_eq!(series.len(), 4);
        assert!(series.iter().all(|s| !s.points.is_empty()));
        // CDF fractions end at 1.0.
        for s in &series {
            assert!((s.points.last().unwrap().1 - 1.0).abs() < 1e-9);
        }
        // Parallel sweep: same series, same order.
        let par = fig6(3, &cfg, &Sweep::new(4));
        for (a, b) in series.iter().zip(&par) {
            assert_eq!(a.label, b.label);
            assert_eq!(a.points, b.points);
        }
    }

    #[test]
    fn figure_csvs_written() {
        let dir = std::env::temp_dir().join("uwfq_figs_test");
        std::fs::create_dir_all(&dir).unwrap();
        let d = dir.to_str().unwrap();
        write_fig3_csv(d, &fig3(&base(), &Sweep::seq())).unwrap();
        write_fig4_csv(d, &fig4(&base(), &Sweep::seq())).unwrap();
        assert!(dir.join("fig3_gantt.csv").exists());
        assert!(dir.join("fig3_completion.csv").exists());
        assert!(dir.join("fig4_inversion.csv").exists());
        std::fs::remove_dir_all(dir).ok();
    }
}
