//! Fairness-under-failure degradation curves (`uwfq fault`,
//! `BENCH_fault.json`): UWFQ vs Fair vs FIFO across increasing task
//! failure rates, plus a straggler/speculation arm and a crash/blacklist
//! arm.
//!
//! The question the grid answers: does UWFQ's fairness advantage survive
//! re-execution noise? Virtual time is charged once per job at arrival,
//! so retries, killed speculation clones and crash-lost attempts consume
//! cores without moving any job in the virtual order — per-user *goodput*
//! stays proportional to entitlement while per-user wasted core-time
//! shows up separately in the ledger.

use crate::config::Config;
use crate::core::job::JobSpec;
use crate::fault::FaultConfig;
use crate::sched::PolicyKind;
use crate::sweep::Sweep;
use crate::util::benchkit::JsonSink;

/// One (policy × fault arm) grid cell.
pub struct FaultCell {
    /// Fault arm name (`clean`, `fail02`, ... `straggle`, `crash`).
    pub arm: &'static str,
    /// Policy label ("UWFQ", "Fair", "FIFO").
    pub label: String,
    pub mean_rt: f64,
    pub worst10_rt: f64,
    /// Jain fairness index over per-user mean response times.
    pub jain: f64,
    pub utilization: f64,
    pub failures: u64,
    pub retries: u64,
    pub spec_wins: u64,
    pub spec_losses: u64,
    pub crashes: u64,
    pub good_core_s: f64,
    pub wasted_core_s: f64,
}

pub struct FaultBench {
    pub cells: Vec<FaultCell>,
    pub jobs: usize,
    pub users: usize,
}

/// The degradation-curve policies, strongest fairness machinery first.
const POLICIES: [PolicyKind; 3] = [PolicyKind::Uwfq, PolicyKind::Fair, PolicyKind::Fifo];

/// The fault arms of the grid. `clean` anchors the curve at zero rates
/// (and doubles as a live check that the fault fields stay inert).
fn arms(quick: bool) -> Vec<(&'static str, FaultConfig)> {
    let fail = |p: f64| FaultConfig {
        task_fail_prob: p,
        retry_backoff_s: 0.25,
        ..Default::default()
    };
    vec![
        ("clean", FaultConfig::default()),
        ("fail02", fail(0.02)),
        ("fail05", fail(0.05)),
        ("fail10", fail(0.10)),
        (
            "straggle",
            FaultConfig {
                straggler_prob: 0.1,
                straggler_mult: 4.0,
                spec_mult: 2.0,
                ..Default::default()
            },
        ),
        (
            "crash",
            FaultConfig {
                crash_mttf_s: if quick { 40.0 } else { 120.0 },
                crash_recover_s: 15.0,
                ..Default::default()
            },
        ),
    ]
}

/// The bench workload: a deterministic multi-user mix with same-instant
/// bursts and skewed per-user activity (the differential-test shape,
/// sized for the bench).
fn workload(quick: bool, seed: u64) -> Vec<JobSpec> {
    let n = if quick { 48 } else { 160 };
    (0..n)
        .map(|i| {
            let user = ((i * 7 + seed as usize) % 8) as u32;
            let arrival_s = if i % 5 == 0 {
                (i / 5) as f64 * 0.3
            } else {
                i as f64 * 0.06
            };
            let compute = 0.3 + ((i * 13) % 9) as f64 * 0.35;
            JobSpec::three_phase(
                user,
                &format!("f{i}"),
                crate::s_to_us(arrival_s),
                compute,
                (32 + (i as u64 % 5) * 32) << 20,
                4,
                None,
            )
        })
        .collect()
}

/// Jain's fairness index over per-user mean response times: 1 = every
/// user sees the same mean RT, 1/n = one user absorbs everything.
fn jain_over_user_rt(completed: &[crate::core::dag::CompletedJob]) -> f64 {
    let mut per_user: std::collections::BTreeMap<u32, (f64, u64)> = Default::default();
    for c in completed {
        let e = per_user.entry(c.user).or_insert((0.0, 0));
        e.0 += c.response_time();
        e.1 += 1;
    }
    let means: Vec<f64> = per_user.values().map(|&(s, n)| s / n as f64).collect();
    let sum: f64 = means.iter().sum();
    let sq: f64 = means.iter().map(|x| x * x).sum();
    if sq > 0.0 {
        sum * sum / (means.len() as f64 * sq)
    } else {
        1.0
    }
}

/// Run the full grid (policies × fault arms) through the sweep engine.
pub fn run_fault(base: &Config, quick: bool, swp: &Sweep) -> FaultBench {
    let jobs = workload(quick, base.seed);
    let users = {
        let mut u: Vec<u32> = jobs.iter().map(|j| j.user).collect();
        u.sort_unstable();
        u.dedup();
        u.len()
    };
    let mut cells_cfg: Vec<(usize, usize, Config)> = Vec::new();
    let arm_list = arms(quick);
    for (ai, (_, fc)) in arm_list.iter().enumerate() {
        for (pi, &policy) in POLICIES.iter().enumerate() {
            let mut cfg = base.clone().with_policy(policy);
            cfg.fault = fc.clone();
            cells_cfg.push((ai, pi, cfg));
        }
    }
    let cells = swp.run(&cells_cfg, |ctx, (ai, _pi, cfg)| {
        let report = ctx.simulate(cfg, jobs.clone());
        let mut rts: Vec<f64> = report.completed.iter().map(|c| c.response_time()).collect();
        rts.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean_rt = rts.iter().sum::<f64>() / rts.len().max(1) as f64;
        let k = (rts.len() / 10).max(1);
        let worst10_rt = rts[rts.len() - k..].iter().sum::<f64>() / k as f64;
        let f = &report.fault;
        FaultCell {
            arm: arm_list[*ai].0,
            label: report.label.clone(),
            mean_rt,
            worst10_rt,
            jain: jain_over_user_rt(&report.completed),
            utilization: report.utilization,
            failures: f.failures,
            retries: f.retries,
            spec_wins: f.spec_wins,
            spec_losses: f.spec_losses,
            crashes: f.crashes,
            good_core_s: f.good_core_s(),
            wasted_core_s: f.wasted_core_s(),
        }
    });
    FaultBench {
        cells,
        jobs: jobs.len(),
        users,
    }
}

pub fn render(b: &FaultBench) -> String {
    let header = [
        "arm", "policy", "RT avg", "RT w10", "Jain", "util", "fail", "retry", "spec+",
        "spec-", "crash", "waste core-s",
    ];
    let rows: Vec<Vec<String>> = b
        .cells
        .iter()
        .map(|c| {
            vec![
                c.arm.to_string(),
                c.label.clone(),
                super::fmt2(c.mean_rt),
                super::fmt2(c.worst10_rt),
                format!("{:.3}", c.jain),
                super::fmt2(c.utilization),
                c.failures.to_string(),
                c.retries.to_string(),
                c.spec_wins.to_string(),
                c.spec_losses.to_string(),
                c.crashes.to_string(),
                super::fmt1(c.wasted_core_s),
            ]
        })
        .collect();
    format!(
        "== fault degradation ({} jobs / {} users) ==\n{}",
        b.jobs,
        b.users,
        super::render_table(&header, &rows)
    )
}

pub fn record_metrics(b: &FaultBench, sink: &mut JsonSink) {
    for c in &b.cells {
        let p = format!("fault/{}/{}", c.arm, c.label);
        sink.metric(&format!("{p}/mean_rt_s"), c.mean_rt);
        sink.metric(&format!("{p}/worst10_rt_s"), c.worst10_rt);
        sink.metric(&format!("{p}/jain_user_rt"), c.jain);
        sink.metric(&format!("{p}/utilization"), c.utilization);
        sink.metric(&format!("{p}/retries"), c.retries as f64);
        sink.metric(&format!("{p}/wasted_core_s"), c.wasted_core_s);
        sink.metric(&format!("{p}/good_core_s"), c.good_core_s);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_grid_runs_and_clean_arm_is_faultless() {
        let mut base = Config::default();
        base.cores = 8;
        let b = run_fault(&base, true, &Sweep::seq());
        assert_eq!(b.cells.len(), POLICIES.len() * arms(true).len());
        for c in b.cells.iter().filter(|c| c.arm == "clean") {
            assert_eq!(c.failures + c.retries + c.crashes, 0, "{}", c.label);
            assert_eq!(c.wasted_core_s, 0.0, "{}", c.label);
            assert!(c.jain > 0.0 && c.jain <= 1.0 + 1e-12);
        }
        // Fault arms actually injected something somewhere.
        assert!(b.cells.iter().any(|c| c.failures > 0));
        assert!(b.cells.iter().any(|c| c.arm == "crash" && c.crashes > 0));
        // Every arm completed the whole workload (RTs well-defined).
        assert!(b.cells.iter().all(|c| c.mean_rt > 0.0));
    }

    #[test]
    fn grid_is_deterministic() {
        let mut base = Config::default();
        base.cores = 8;
        let a = run_fault(&base, true, &Sweep::seq());
        let b = run_fault(&base, true, &Sweep::new(4));
        for (x, y) in a.cells.iter().zip(&b.cells) {
            assert_eq!((x.arm, &x.label), (y.arm, &y.label));
            assert_eq!(x.mean_rt.to_bits(), y.mean_rt.to_bits());
            assert_eq!(x.retries, y.retries);
            assert_eq!(x.wasted_core_s.to_bits(), y.wasted_core_s.to_bits());
        }
    }
}
