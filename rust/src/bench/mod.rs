//! The experiment harness: runs (scheduler × partitioner × workload)
//! grids through the simulator and regenerates every table and figure of
//! the paper's evaluation (§5).
//!
//! * [`tables`] — Table 1 (micro scenarios) and Table 2 (macro).
//! * [`figures`] — Fig. 3 (skew), Fig. 4 (priority inversion), Fig. 5/6
//!   (CDFs), Fig. 7 (per-user violations).

pub mod figures;
pub mod tables;

use std::collections::HashMap;

use crate::config::Config;
use crate::metrics::report::RunMetrics;
use crate::sim;
use crate::workload::Workload;

/// Idle-system response time per distinct job name under `cfg`
/// (slowdown denominators, computed once per job shape).
pub fn idle_map(cfg: &Config, workload: &Workload) -> HashMap<String, f64> {
    let mut map = HashMap::new();
    for job in &workload.jobs {
        if !map.contains_key(&job.name) {
            map.insert(job.name.clone(), sim::idle_response_time(cfg, job));
        }
    }
    map
}

/// Run one (config, workload) experiment end to end and aggregate
/// metrics. Deterministic for a given config seed.
pub fn run_one(cfg: &Config, workload: &Workload) -> RunMetrics {
    let idle = idle_map(cfg, workload);
    let report = sim::simulate(cfg.clone(), workload.jobs.clone());
    RunMetrics::build(
        &report.label,
        workload,
        &report.completed,
        &idle,
        report.makespan_s,
        report.utilization,
    )
}

/// Run the UJF reference for a given scheme (the fairness baseline the
/// DVR/DSR metrics compare against; §5.1.1).
pub fn run_ujf_reference(cfg: &Config, workload: &Workload) -> RunMetrics {
    let ujf_cfg = cfg.clone().with_policy(crate::sched::PolicyKind::Ujf);
    run_one(&ujf_cfg, workload)
}

/// Render an aligned text table.
pub fn render_table(header: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let mut out = String::new();
    let fmt_row = |cells: &[String], widths: &[usize]| -> String {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:>width$}", c, width = widths[i]))
            .collect::<Vec<_>>()
            .join("  ")
    };
    let head: Vec<String> = header.iter().map(|s| s.to_string()).collect();
    out.push_str(&fmt_row(&head, &widths));
    out.push('\n');
    out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
    out.push('\n');
    for row in rows {
        out.push_str(&fmt_row(row, &widths));
        out.push('\n');
    }
    out
}

pub fn fmt1(x: f64) -> String {
    format!("{x:.1}")
}
pub fn fmt2(x: f64) -> String {
    format!("{x:.2}")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::PolicyKind;
    use crate::workload::scenarios;

    #[test]
    fn run_one_produces_complete_metrics() {
        let w = scenarios::scenario2(1, 4, 0.5); // small: 16 tiny jobs
        let cfg = Config::default().with_policy(PolicyKind::Uwfq).with_cores(8);
        let m = run_one(&cfg, &w);
        assert_eq!(m.outcomes.len(), 16);
        assert!(m.mean_rt() > 0.0);
        assert!(m.outcomes.iter().all(|o| o.idle_rt > 0.0));
        assert!(m.makespan_s > 0.0);
    }

    #[test]
    fn idle_map_one_entry_per_name() {
        let w = scenarios::scenario2(1, 3, 0.5);
        let cfg = Config::default().with_cores(8);
        let idle = idle_map(&cfg, &w);
        assert_eq!(idle.len(), 1); // all jobs are "tiny"
        assert!(idle["tiny"] > 0.0);
    }

    #[test]
    fn render_table_aligns() {
        let t = render_table(
            &["a", "bbbb"],
            &[vec!["1".into(), "2".into()], vec!["333".into(), "4".into()]],
        );
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 4);
        assert_eq!(lines[0].len(), lines[2].len());
    }
}
