//! The experiment harness: runs (scheduler × partitioner × workload)
//! grids through the simulator and regenerates every table and figure of
//! the paper's evaluation (§5).
//!
//! * [`tables`] — Table 1 (micro scenarios) and Table 2 (macro).
//! * [`figures`] — Fig. 3 (skew), Fig. 4 (priority inversion), Fig. 5/6
//!   (CDFs), Fig. 7 (per-user violations).
//! * [`scale`] — the streaming million-job harness (`uwfq scale`,
//!   `BENCH_scale.json`).
//! * [`replay`] — the streaming trace-replay harness (`uwfq replay`,
//!   `BENCH_replay.json`).
//! * [`fault`] — fairness-under-failure degradation curves (`uwfq
//!   fault`, `BENCH_fault.json`).
//! * [`drf`] — the multi-resource grids: seven policies over mixed
//!   CPU/memory demands plus the UWFQ-vs-BoPF burst-tolerance ablation
//!   (`uwfq drf`, `BENCH_drf.json`).
//! * [`hotpath`] — event-core throughput: wheel vs heap backends plus
//!   the batching ablation (`uwfq hotpath`, `BENCH_hotpath.json`).
//! * [`summary`] — merges every `BENCH_*.json` artifact into one
//!   markdown perf-trajectory table (`uwfq benchsummary`).
//!
//! Every grid is expressed as a list of independent cells over the
//! [`crate::sweep`] engine: the caller passes a [`crate::sweep::Sweep`]
//! handle — `Sweep::seq()` for the sequential reference, `Sweep::new(n)`
//! for n-worker execution with byte-identical output.

pub mod drf;
pub mod fault;
pub mod figures;
pub mod hotpath;
pub mod replay;
pub mod scale;
pub mod shard;
pub mod summary;
pub mod tables;

use std::collections::HashMap;
use std::sync::Arc;

use crate::config::Config;
use crate::metrics::report::RunMetrics;
use crate::sim::SimCtx;
use crate::workload::Workload;

/// Idle-system response time per distinct job name under `cfg`
/// (slowdown denominators, computed once per job shape and memoized
/// process-wide by template — see [`crate::sim::idle_response_time`]).
pub fn idle_map(cfg: &Config, workload: &Workload) -> HashMap<Arc<str>, f64> {
    idle_map_in(&mut SimCtx::new(), cfg, workload)
}

/// [`idle_map`] on a reusable simulation context (sweep-worker path).
pub fn idle_map_in(
    ctx: &mut SimCtx,
    cfg: &Config,
    workload: &Workload,
) -> HashMap<Arc<str>, f64> {
    let mut map = HashMap::new();
    for job in &workload.jobs {
        if !map.contains_key(&job.name) {
            map.insert(job.name.clone(), ctx.idle_response_time(cfg, job));
        }
    }
    map
}

/// Run one (config, workload) experiment end to end and aggregate
/// metrics. Deterministic for a given config seed.
pub fn run_one(cfg: &Config, workload: &Workload) -> RunMetrics {
    run_one_in(&mut SimCtx::new(), cfg, workload)
}

/// [`run_one`] on a reusable simulation context — the grid-cell body:
/// sweep workers call this with their per-worker context so one
/// `SchedCore`'s allocations serve every cell the worker claims.
pub fn run_one_in(ctx: &mut SimCtx, cfg: &Config, workload: &Workload) -> RunMetrics {
    let idle = idle_map_in(ctx, cfg, workload);
    let report = ctx.simulate(cfg, workload.jobs.clone());
    RunMetrics::build(
        &report.label,
        workload,
        &report.completed,
        &idle,
        report.makespan_s,
        report.utilization,
    )
}

/// Run the UJF reference for a given scheme (the fairness baseline the
/// DVR/DSR metrics compare against; §5.1.1).
pub fn run_ujf_reference(cfg: &Config, workload: &Workload) -> RunMetrics {
    let ujf_cfg = cfg.clone().with_policy(crate::sched::PolicyKind::Ujf);
    run_one(&ujf_cfg, workload)
}

/// The partitioning schemes of the paper's macro grids (Table 2 / Fig 7
/// iterate exactly these, in this order).
pub(crate) const TABLE_SCHEMES: [crate::partition::SchemeKind; 2] = [
    crate::partition::SchemeKind::Size,
    crate::partition::SchemeKind::Runtime,
];

/// The paper-table row configs for one base config: the UJF reference
/// first (cell 0), then every non-UJF paper scheduler in table order —
/// the standard cell list for Table 1/2 and Fig. 7 grids.
pub(crate) fn paper_cells(base: &Config) -> Vec<Config> {
    let mut cells = vec![base.clone().with_policy(crate::sched::PolicyKind::Ujf)];
    for policy in crate::sched::PolicyKind::PAPER {
        if policy != crate::sched::PolicyKind::Ujf {
            cells.push(base.clone().with_policy(policy));
        }
    }
    cells
}

/// Simulation cells in one paper grid group (UJF reference + non-UJF
/// rows) — the unit Table 1/2 and Fig. 7 grids are built from.
fn paper_cell_count() -> usize {
    paper_cells(&Config::default()).len()
}

/// Cells in the Table-2 + Fig-7 macro grid — the `BENCH_sweep` speedup
/// probe's denominator, derived from the actual grid definitions so
/// cells/s metrics track any change to the policy or scheme lists.
pub fn macro_grid_cell_count() -> usize {
    2 * TABLE_SCHEMES.len() * paper_cell_count()
}

/// Cells in the combined Table-1 grid (both micro scenarios).
pub fn table1_grid_cell_count() -> usize {
    2 * paper_cell_count()
}

/// Render an aligned text table.
pub fn render_table(header: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let mut out = String::new();
    let fmt_row = |cells: &[String], widths: &[usize]| -> String {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:>width$}", c, width = widths[i]))
            .collect::<Vec<_>>()
            .join("  ")
    };
    let head: Vec<String> = header.iter().map(|s| s.to_string()).collect();
    out.push_str(&fmt_row(&head, &widths));
    out.push('\n');
    out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
    out.push('\n');
    for row in rows {
        out.push_str(&fmt_row(row, &widths));
        out.push('\n');
    }
    out
}

pub fn fmt1(x: f64) -> String {
    format!("{x:.1}")
}
pub fn fmt2(x: f64) -> String {
    format!("{x:.2}")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::PolicyKind;
    use crate::workload::test_scenario2;

    #[test]
    fn run_one_produces_complete_metrics() {
        let w = test_scenario2(1, 4, 0.5); // small: 16 tiny jobs
        let cfg = Config::default().with_policy(PolicyKind::Uwfq).with_cores(8);
        let m = run_one(&cfg, &w);
        assert_eq!(m.outcomes.len(), 16);
        assert!(m.mean_rt() > 0.0);
        assert!(m.outcomes.iter().all(|o| o.idle_rt > 0.0));
        assert!(m.makespan_s > 0.0);
    }

    #[test]
    fn idle_map_one_entry_per_name() {
        let w = test_scenario2(1, 3, 0.5);
        let cfg = Config::default().with_cores(8);
        let idle = idle_map(&cfg, &w);
        assert_eq!(idle.len(), 1); // all jobs are "tiny"
        assert!(idle["tiny"] > 0.0);
    }

    #[test]
    fn grid_cell_counts_match_definitions() {
        // Pin the derived counts: 2 schemes × (1 UJF ref + 3 rows) and
        // 2 scenarios × 4 — updated consciously if PAPER/schemes change.
        assert_eq!(macro_grid_cell_count(), 16);
        assert_eq!(table1_grid_cell_count(), 8);
    }

    #[test]
    fn render_table_aligns() {
        let t = render_table(
            &["a", "bbbb"],
            &[vec!["1".into(), "2".into()], vec!["333".into(), "4".into()]],
        );
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 4);
        assert_eq!(lines[0].len(), lines[2].len());
    }
}
