//! `uwfq benchsummary` — merge every `BENCH_*.json` artifact into one
//! markdown perf-trajectory table.
//!
//! Each bench harness (`scale`, `replay`, `fault`, `hotpath`, `shard`)
//! writes a [`crate::util::benchkit::JsonSink`] file whose `"metrics"`
//! object maps flat metric names to numbers. This module scans a list of
//! directories for `BENCH_*.json` files, parses them with the in-tree
//! JSON reader, and renders one `artifact | metric | value` markdown
//! table — so pinning the perf baseline from a CI artifact set is a
//! single command and a paste.
//!
//! Determinism: directories are scanned in the given order, files sorted
//! by name within each, duplicate artifact stems deduplicated (first
//! directory wins), and metric keys are already sorted (`BTreeMap` in
//! the sink and the parser).

use std::collections::{BTreeMap, BTreeSet};
use std::fs;
use std::path::{Path, PathBuf};

use crate::util::jsonout::{self, Json};

/// One parsed artifact: the file stem (e.g. `BENCH_shard-skew-on`) plus
/// its sorted metric map.
#[derive(Clone, Debug)]
pub struct BenchArtifact {
    pub name: String,
    pub metrics: BTreeMap<String, f64>,
}

/// Find `BENCH_*.json` files directly inside each of `dirs`
/// (non-recursive). Files sort by name within a directory; a stem seen
/// in an earlier directory shadows later ones. Unreadable directories
/// are skipped — an empty result is not an error.
pub fn find_artifacts(dirs: &[String]) -> Vec<PathBuf> {
    let mut seen: BTreeSet<String> = BTreeSet::new();
    let mut out = Vec::new();
    for d in dirs {
        let Ok(rd) = fs::read_dir(d) else { continue };
        let mut files: Vec<PathBuf> = rd
            .filter_map(|e| e.ok())
            .map(|e| e.path())
            .filter(|p| {
                matches!(
                    p.file_name().and_then(|n| n.to_str()),
                    Some(n) if n.starts_with("BENCH_") && n.ends_with(".json")
                )
            })
            .collect();
        files.sort();
        for f in files {
            let stem = f
                .file_stem()
                .and_then(|s| s.to_str())
                .unwrap_or_default()
                .to_string();
            if seen.insert(stem) {
                out.push(f);
            }
        }
    }
    out
}

/// Parse one artifact's `"metrics"` object. Non-numeric entries are
/// ignored; a missing `"metrics"` key yields an empty map (the file may
/// predate the metrics convention) — malformed JSON is an error naming
/// the file.
pub fn load_artifact(path: &Path) -> Result<BenchArtifact, String> {
    let text =
        fs::read_to_string(path).map_err(|e| format!("{}: {e}", path.display()))?;
    let json = jsonout::parse(&text).map_err(|e| format!("{}: {e}", path.display()))?;
    let mut metrics = BTreeMap::new();
    if let Some(Json::Obj(m)) = json.get("metrics") {
        for (k, v) in m {
            if let Some(n) = v.as_f64() {
                metrics.insert(k.clone(), n);
            }
        }
    }
    Ok(BenchArtifact {
        name: path
            .file_stem()
            .and_then(|s| s.to_str())
            .unwrap_or_default()
            .to_string(),
        metrics,
    })
}

/// Integers print bare, everything else with 4 decimals — enough to
/// compare jobs/s and ratios across PRs without float noise.
fn fmt_metric(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v:.4}")
    }
}

/// Render the merged markdown table.
pub fn render_markdown(arts: &[BenchArtifact]) -> String {
    let mut s = String::from("# Bench trajectory\n\n");
    if arts.is_empty() {
        s.push_str("_No BENCH_*.json artifacts found._\n");
        return s;
    }
    s.push_str("| artifact | metric | value |\n|---|---|---:|\n");
    for a in arts {
        for (k, v) in &a.metrics {
            s.push_str(&format!("| {} | {k} | {} |\n", a.name, fmt_metric(*v)));
        }
    }
    s
}

/// The whole subcommand body: scan, parse, render. Errors only on a
/// malformed artifact — no artifacts at all is a valid (empty) table.
pub fn summarize(dirs: &[String]) -> Result<String, String> {
    let mut arts = Vec::new();
    for path in find_artifacts(dirs) {
        arts.push(load_artifact(&path)?);
    }
    Ok(render_markdown(&arts))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::benchkit::JsonSink;

    fn write_bench(dir: &Path, name: &str, metrics: &[(&str, f64)]) {
        let mut sink = JsonSink::new();
        for (k, v) in metrics {
            sink.metric(k, *v);
        }
        sink.write(dir.join(name).to_str().unwrap()).unwrap();
    }

    #[test]
    fn summarize_merges_sorted_and_dedups() {
        let base = std::env::temp_dir().join("uwfq_benchsummary_test");
        let (a, b) = (base.join("a"), base.join("b"));
        fs::create_dir_all(&a).unwrap();
        fs::create_dir_all(&b).unwrap();
        write_bench(&a, "BENCH_zz.json", &[("z/jobs_per_s", 1234.0)]);
        write_bench(&a, "BENCH_aa.json", &[("a/speedup", 1.5), ("a/jobs", 100.0)]);
        // Same stem in the second dir must be shadowed by the first.
        write_bench(&b, "BENCH_zz.json", &[("z/jobs_per_s", 9999.0)]);
        write_bench(&b, "BENCH_only.json", &[("o/x", 0.25)]);
        fs::write(a.join("not_a_bench.json"), "{}").unwrap();

        let dirs = vec![
            a.to_str().unwrap().to_string(),
            b.to_str().unwrap().to_string(),
            base.join("missing").to_str().unwrap().to_string(),
        ];
        let found = find_artifacts(&dirs);
        assert_eq!(found.len(), 3, "{found:?}");
        // Sorted within dir a, then dir b's new stem.
        assert!(found[0].ends_with("a/BENCH_aa.json"));
        assert!(found[1].ends_with("a/BENCH_zz.json"));
        assert!(found[2].ends_with("b/BENCH_only.json"));

        let md = summarize(&dirs).unwrap();
        assert!(md.contains("| artifact | metric | value |"), "{md}");
        assert!(md.contains("| BENCH_aa | a/jobs | 100 |"), "{md}");
        assert!(md.contains("| BENCH_aa | a/speedup | 1.5000 |"), "{md}");
        assert!(md.contains("| BENCH_zz | z/jobs_per_s | 1234 |"), "{md}");
        assert!(!md.contains("9999"), "shadowed artifact leaked: {md}");
        // Metric keys sorted within an artifact.
        let jobs = md.find("a/jobs").unwrap();
        let speedup = md.find("a/speedup").unwrap();
        assert!(jobs < speedup);

        fs::remove_dir_all(&base).ok();
    }

    #[test]
    fn summarize_handles_empty_and_malformed() {
        let base = std::env::temp_dir().join("uwfq_benchsummary_bad_test");
        fs::create_dir_all(&base).unwrap();
        let md = summarize(&[base.to_str().unwrap().to_string()]).unwrap();
        assert!(md.contains("No BENCH_"), "{md}");
        fs::write(base.join("BENCH_broken.json"), "{ not json").unwrap();
        let err = summarize(&[base.to_str().unwrap().to_string()]).unwrap_err();
        assert!(err.contains("BENCH_broken"), "{err}");
        fs::remove_dir_all(&base).ok();
    }
}
