//! Event-core throughput bench (`uwfq hotpath`, `BENCH_hotpath.json`).
//!
//! Measures end-to-end simulator throughput (task-events/s) on the
//! congested 50 000-job / 100-user / 64-core case — 2 000 jobs under
//! `--quick` (the CI smoke shape) — for every policy across the
//! event-core ablation cells:
//!
//! * `heap_perevent`  — binary heap, per-event offers/notifications
//!   (the executable reference, what `UWFQ_EVENT_HEAP=1` selects);
//! * `wheel_perevent` — calendar queue, per-event processing (isolates
//!   the queue-structure win);
//! * `wheel_batched`  — calendar queue + same-timestamp batching (the
//!   default event core);
//! * `default_env`    — whatever [`SimOpts::from_env`] resolves, so a
//!   run under `UWFQ_EVENT_HEAP=1` produces a comparable artifact for
//!   the escape-hatch path.
//!
//! All cells replay byte-identical schedules (`tests/invariants.rs`
//! holds the differential), so events/s ratios are pure event-core
//! cost. The cargo bench twin (`cargo bench --bench hotpath`) carries
//! the micro-bench arms; this harness is the CI artifact path.

use std::time::Instant;

use crate::config::Config;
use crate::core::job::JobSpec;
use crate::sched::PolicyKind;
use crate::sim::{self, EventBackend, SimOpts};
use crate::util::benchkit::{black_box, JsonSink};

/// The explicit ablation cells, reference first.
pub const ARMS: [(&str, SimOpts); 3] = [
    ("heap_perevent", SimOpts { backend: EventBackend::Heap, batch: false }),
    ("wheel_perevent", SimOpts { backend: EventBackend::Wheel, batch: false }),
    ("wheel_batched", SimOpts { backend: EventBackend::Wheel, batch: true }),
];

/// One measured (policy × event-core) cell.
pub struct Cell {
    pub policy: PolicyKind,
    /// Arm name (`ARMS` entry, or `default_env`).
    pub arm: &'static str,
    pub mean_s: f64,
    pub events_per_s: f64,
}

pub struct HotpathOutcome {
    pub jobs: usize,
    pub users: u32,
    pub cores: u32,
    pub iters: u32,
    /// Task events per run (identical across arms — same schedule).
    pub task_events: usize,
    pub cells: Vec<Cell>,
}

impl HotpathOutcome {
    /// Events/s of `arm` under `policy`, if measured.
    pub fn rate(&self, policy: PolicyKind, arm: &str) -> Option<f64> {
        self.cells
            .iter()
            .find(|c| c.policy == policy && c.arm == arm)
            .map(|c| c.events_per_s)
    }

    /// `wheel_batched` speedup over the heap per-event reference.
    pub fn speedup(&self, policy: PolicyKind) -> Option<f64> {
        let fast = self.rate(policy, "wheel_batched")?;
        let slow = self.rate(policy, "heap_perevent")?;
        Some(fast / slow)
    }
}

/// The congested multi-user workload: `n` jobs over `users` users
/// arriving every `gap_us` (the shape `benches/hotpath.rs` scales on).
fn workload(n: usize, users: u32, gap_us: u64) -> Vec<JobSpec> {
    (0..n)
        .map(|i| {
            JobSpec::three_phase(
                (i as u32) % users,
                &format!("j{i}"),
                (i as u64) * gap_us,
                2.0,
                128 << 20,
                4,
                None,
            )
        })
        .collect()
}

fn time_runs<F: FnMut()>(iters: u32, mut f: F) -> f64 {
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    t0.elapsed().as_secs_f64() / iters as f64
}

/// Run the event-core bench. `base` supplies cores/seed (the CLI
/// defaults cores to 64); `quick` shrinks to the CI smoke shape.
pub fn run_hotpath(base: &Config, quick: bool) -> HotpathOutcome {
    let n = if quick { 2_000 } else { 50_000 };
    run_hotpath_sized(base, n, 2)
}

/// [`run_hotpath`] with an explicit job count and iteration count (the
/// unit test drives a tiny shape through the full cell matrix).
pub fn run_hotpath_sized(base: &Config, n: usize, iters: u32) -> HotpathOutcome {
    let users = 100u32;
    let mut cfg = base.clone();
    cfg.task_overhead = 0.005;
    let jobs = workload(n, users, 4_000);

    // Task-event count from one logged probe run (arm-independent: all
    // cells replay the same schedule).
    let mut probe = cfg.clone();
    probe.log_tasks = true;
    let task_events = sim::simulate_opts(probe, jobs.clone(), ARMS[0].1).task_log.len();

    let mut cells = Vec::new();
    for policy in PolicyKind::ALL {
        let c = cfg.clone().with_policy(policy);
        for (arm, opts) in ARMS {
            let mean_s = time_runs(iters, || {
                black_box(sim::simulate_opts(c.clone(), jobs.clone(), opts));
            });
            cells.push(Cell {
                policy,
                arm,
                mean_s,
                events_per_s: task_events as f64 / mean_s,
            });
        }
        // The env-resolved default: under `UWFQ_EVENT_HEAP=1` this is
        // the heap fallback, giving CI a per-backend artifact from the
        // exact path production callers take.
        let mean_s = time_runs(iters, || {
            black_box(sim::simulate(c.clone(), jobs.clone()));
        });
        cells.push(Cell {
            policy,
            arm: "default_env",
            mean_s,
            events_per_s: task_events as f64 / mean_s,
        });
    }
    HotpathOutcome {
        jobs: n,
        users,
        cores: cfg.cores,
        iters,
        task_events,
        cells,
    }
}

pub fn render(o: &HotpathOutcome) -> String {
    let mut out = format!(
        "event core: {} jobs / {} users / {} cores, {} task events/run \
         (mean of {} iters)\n",
        o.jobs, o.users, o.cores, o.task_events, o.iters
    );
    let rows: Vec<Vec<String>> = o
        .cells
        .iter()
        .map(|c| {
            vec![
                c.policy.name().to_string(),
                c.arm.to_string(),
                super::fmt2(c.events_per_s / 1e6),
                super::fmt2(c.mean_s * 1e3),
            ]
        })
        .collect();
    out.push_str(&super::render_table(
        &["policy", "event core", "Mev/s", "ms/run"],
        &rows,
    ));
    for policy in PolicyKind::ALL {
        if let Some(s) = o.speedup(policy) {
            out.push_str(&format!(
                "{}: wheel+batch {:.2}x over heap per-event\n",
                policy.name(),
                s
            ));
        }
    }
    out
}

pub fn record_metrics(o: &HotpathOutcome, sink: &mut JsonSink) {
    sink.metric("hotpath/jobs", o.jobs as f64);
    sink.metric("hotpath/task_events", o.task_events as f64);
    let heap_default = SimOpts::from_env().backend == EventBackend::Heap;
    sink.metric("hotpath/default_env_is_heap", heap_default as u64 as f64);
    for c in &o.cells {
        sink.metric(
            &format!("hotpath/{}/{}/task_events_per_s", c.policy.name(), c.arm),
            c.events_per_s,
        );
    }
    for policy in PolicyKind::ALL {
        if let Some(s) = o.speedup(policy) {
            sink.metric(&format!("hotpath/{}/speedup_wheel_batched", policy.name()), s);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn outcome_rates_and_speedup() {
        let o = HotpathOutcome {
            jobs: 10,
            users: 2,
            cores: 4,
            iters: 1,
            task_events: 1000,
            cells: vec![
                Cell {
                    policy: PolicyKind::Fifo,
                    arm: "heap_perevent",
                    mean_s: 2.0,
                    events_per_s: 500.0,
                },
                Cell {
                    policy: PolicyKind::Fifo,
                    arm: "wheel_batched",
                    mean_s: 0.5,
                    events_per_s: 2000.0,
                },
            ],
        };
        assert_eq!(o.rate(PolicyKind::Fifo, "heap_perevent"), Some(500.0));
        assert_eq!(o.speedup(PolicyKind::Fifo), Some(4.0));
        assert!(o.speedup(PolicyKind::Uwfq).is_none());
        let txt = render(&o);
        assert!(txt.contains("wheel+batch 4.00x"), "{txt}");
    }

    #[test]
    fn tiny_run_measures_every_arm() {
        // Tiny shape (not the CI smoke size): every (policy, arm) cell
        // present with a positive rate and a computable speedup.
        let base = Config::default().with_cores(8);
        let o = run_hotpath_sized(&base, 60, 1);
        assert!(o.task_events > 0);
        assert_eq!(o.cells.len(), PolicyKind::ALL.len() * (ARMS.len() + 1));
        for c in &o.cells {
            assert!(c.events_per_s > 0.0, "{} {}", c.policy.name(), c.arm);
        }
        for policy in PolicyKind::ALL {
            assert!(o.speedup(policy).expect("speedup cell") > 0.0);
        }
        let mut sink = JsonSink::new();
        record_metrics(&o, &mut sink);
    }
}
