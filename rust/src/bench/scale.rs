//! The scale harness behind `uwfq scale` and `benches/scale.rs`:
//! million-job / ten-thousand-user runs through the streaming pipeline
//! ([`crate::workload::stream::scale_stream`] →
//! [`crate::sim::simulate_stream_into`] →
//! [`crate::metrics::streaming::StreamingRunMetrics`]), with an optional
//! exact reference pass that measures the streaming estimators' error.
//!
//! Memory model: the timed run's resident metric state is O(in-flight
//! jobs + users) — the engine's slab arenas (peak concurrency), the
//! stream's per-user generators, and the sink's accumulators. No per-job
//! outcome is retained. The verify pass is a *separate* run that keeps
//! one bare `f64` response time per job (8 B/job) purely to compute the
//! streaming-vs-exact error columns of `BENCH_scale.json`; both runs are
//! deterministic, so the comparison is apples-to-apples.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

use crate::config::Config;
use crate::core::dag::CompletedJob;
use crate::core::SchedCore;
use crate::metrics::streaming::StreamingRunMetrics;
use crate::sim::{self, CompletionSink};
use crate::util::benchkit::JsonSink;
use crate::util::stats;
use crate::workload::stream::{scale_stream, scale_template_jobs, ScaleParams};

/// Documented accuracy contract of the streaming estimators, asserted by
/// `uwfq scale --verify` and CI (`tests/scale_accuracy.rs`). See
/// [`crate::metrics::streaming`] for the derivation.
pub const ECDF_QUANTILE_RTOL: f64 = 0.08;
pub const P2_QUANTILE_RTOL: f64 = 0.15;
pub const P2_P99_RTOL: f64 = 0.25;
pub const ECDF_SUP_TOL: f64 = 0.02;

/// The tracked quantiles.
pub const QUANTILES: [f64; 3] = [0.50, 0.95, 0.99];

/// Streaming-vs-exact error report (the verify pass).
#[derive(Clone, Debug)]
pub struct ScaleVerify {
    /// Exact p50/p95/p99 over all response times.
    pub exact_q: [f64; 3],
    /// Relative error of the ECDF-inverted quantiles.
    pub ecdf_rel_err: [f64; 3],
    /// Relative error of the P² estimates.
    pub p2_rel_err: [f64; 3],
    /// Sup |streaming CDF − exact CDF| over the ECDF's bin edges.
    pub ecdf_sup_err: f64,
}

impl ScaleVerify {
    /// Check the documented tolerances; `Err` describes the first
    /// violation (CI fails the scale-smoke job on it).
    pub fn check(&self) -> Result<(), String> {
        for (i, p) in QUANTILES.iter().enumerate() {
            if self.ecdf_rel_err[i] > ECDF_QUANTILE_RTOL {
                return Err(format!(
                    "ECDF p{} error {:.4} exceeds tolerance {ECDF_QUANTILE_RTOL}",
                    p * 100.0,
                    self.ecdf_rel_err[i]
                ));
            }
            let tol = if (*p - 0.99).abs() < 1e-12 { P2_P99_RTOL } else { P2_QUANTILE_RTOL };
            if self.p2_rel_err[i] > tol {
                return Err(format!(
                    "P² p{} error {:.4} exceeds tolerance {tol}",
                    p * 100.0,
                    self.p2_rel_err[i]
                ));
            }
        }
        if self.ecdf_sup_err > ECDF_SUP_TOL {
            return Err(format!(
                "ECDF sup error {:.4} exceeds tolerance {ECDF_SUP_TOL}",
                self.ecdf_sup_err
            ));
        }
        Ok(())
    }
}

/// Everything one scale run produces.
pub struct ScaleOutcome {
    pub label: String,
    pub jobs: u64,
    pub users: u32,
    pub wall_s: f64,
    pub jobs_per_s: f64,
    pub task_events: u64,
    pub task_events_per_s: f64,
    /// Peak concurrently in-flight jobs (the O(active) bound).
    pub peak_in_flight_jobs: usize,
    /// Engine arena footprints after the run (slots, bounded by peak
    /// concurrency — the resident-state proxy).
    pub arena_job_slots: usize,
    pub arena_stage_slots: usize,
    pub makespan_s: f64,
    pub utilization: f64,
    pub mean_rt: f64,
    pub mean_slowdown: f64,
    pub jain_index: f64,
    pub user_count: usize,
    /// Streaming quantile estimates: ECDF-inverted and P².
    pub ecdf_q: [f64; 3],
    pub p2_q: [f64; 3],
    pub verify: Option<ScaleVerify>,
}

/// Idle response time per scale job template under `cfg` — O(templates)
/// entries, the slowdown denominators of the streaming sink.
pub fn scale_idle_map(cfg: &Config) -> HashMap<Arc<str>, f64> {
    let mut map = HashMap::new();
    for job in scale_template_jobs() {
        let rt = sim::idle_response_time(cfg, &job);
        map.insert(job.name, rt);
    }
    map
}

/// Collects bare response times — the exact reference for the verify
/// pass (8 bytes/job; deliberately NOT `CollectSink`, which would retain
/// whole records).
struct RtSink {
    rts: Vec<f64>,
}

impl CompletionSink for RtSink {
    fn job_completed(&mut self, c: CompletedJob) {
        self.rts.push(c.response_time());
    }
}

/// Run one scale experiment: the timed streaming pass, then (optionally)
/// the exact reference pass for the error columns.
pub fn run_scale(params: &ScaleParams, cfg: &Config, verify: bool) -> ScaleOutcome {
    let idle = scale_idle_map(cfg);
    let mut sink = StreamingRunMetrics::new(&cfg.label(), idle);
    let mut core = SchedCore::from_config(cfg.clone());
    let t0 = Instant::now();
    let summary = sim::simulate_stream_into(&mut core, scale_stream(params), &mut sink);
    let wall_s = t0.elapsed().as_secs_f64().max(1e-9);
    let (arena_job_slots, arena_stage_slots) = core.arena_capacities();

    let ecdf_q = QUANTILES.map(|p| sink.rt_quantile_ecdf(p));
    let p2_q = QUANTILES.map(|p| sink.rt_quantile_p2(p));

    let verify = verify.then(|| {
        let mut rt_sink = RtSink {
            rts: Vec::with_capacity(params.jobs as usize),
        };
        let mut core2 = SchedCore::from_config(cfg.clone());
        sim::simulate_stream_into(&mut core2, scale_stream(params), &mut rt_sink);
        let mut rts = rt_sink.rts;
        rts.sort_by(|a, b| a.partial_cmp(b).expect("finite response time"));
        let exact_q = [
            stats::percentile_sorted(&rts, 50.0),
            stats::percentile_sorted(&rts, 95.0),
            stats::percentile_sorted(&rts, 99.0),
        ];
        let rel = |est: f64, exact: f64| {
            if exact > 0.0 {
                (est - exact).abs() / exact
            } else {
                0.0
            }
        };
        let ecdf_rel_err = [0usize, 1, 2].map(|i| rel(ecdf_q[i], exact_q[i]));
        let p2_rel_err = [0usize, 1, 2].map(|i| rel(p2_q[i], exact_q[i]));
        let exact_at = |v: f64| -> f64 {
            rts.partition_point(|&s| s <= v) as f64 / rts.len() as f64
        };
        let mut sup = 0.0f64;
        for b in 0..sink.rt_ecdf.bins() {
            let edge = sink.rt_ecdf.upper_edge(b);
            sup = sup.max((sink.rt_ecdf.cdf_at(edge) - exact_at(edge)).abs());
        }
        ScaleVerify {
            exact_q,
            ecdf_rel_err,
            p2_rel_err,
            ecdf_sup_err: sup,
        }
    });

    ScaleOutcome {
        label: summary.label,
        jobs: summary.jobs_completed,
        users: params.users,
        wall_s,
        jobs_per_s: summary.jobs_completed as f64 / wall_s,
        task_events: summary.task_events,
        task_events_per_s: summary.task_events as f64 / wall_s,
        peak_in_flight_jobs: summary.peak_in_flight_jobs,
        arena_job_slots,
        arena_stage_slots,
        makespan_s: summary.makespan_s,
        utilization: summary.utilization,
        mean_rt: sink.mean_rt(),
        mean_slowdown: sink.mean_slowdown(),
        jain_index: sink.jain_index_user_rt(),
        user_count: sink.user_count(),
        ecdf_q,
        p2_q,
        verify,
    }
}

/// Record a scale outcome into a benchkit sink (`BENCH_scale.json`
/// metrics, tracked across PRs next to `BENCH_hotpath` / `BENCH_sweep`).
pub fn record_metrics(o: &ScaleOutcome, sink: &mut JsonSink) {
    sink.metric("scale/jobs", o.jobs as f64);
    sink.metric("scale/users", o.users as f64);
    sink.metric("scale/wall_s", o.wall_s);
    sink.metric("scale/jobs_per_s", o.jobs_per_s);
    sink.metric("scale/task_events", o.task_events as f64);
    sink.metric("scale/task_events_per_s", o.task_events_per_s);
    sink.metric("scale/peak_in_flight_jobs", o.peak_in_flight_jobs as f64);
    sink.metric("scale/arena_job_slots", o.arena_job_slots as f64);
    sink.metric("scale/arena_stage_slots", o.arena_stage_slots as f64);
    sink.metric("scale/makespan_s", o.makespan_s);
    sink.metric("scale/utilization", o.utilization);
    sink.metric("scale/mean_rt_s", o.mean_rt);
    sink.metric("scale/mean_slowdown", o.mean_slowdown);
    sink.metric("scale/jain_index_user_rt", o.jain_index);
    for (i, p) in QUANTILES.iter().enumerate() {
        let tag = (p * 100.0).round() as u32;
        sink.metric(&format!("scale/rt_p{tag}_ecdf_s"), o.ecdf_q[i]);
        sink.metric(&format!("scale/rt_p{tag}_p2_s"), o.p2_q[i]);
    }
    if let Some(v) = &o.verify {
        for (i, p) in QUANTILES.iter().enumerate() {
            let tag = (p * 100.0).round() as u32;
            sink.metric(&format!("scale/rt_p{tag}_exact_s"), v.exact_q[i]);
            sink.metric(&format!("scale/rt_p{tag}_ecdf_rel_err"), v.ecdf_rel_err[i]);
            sink.metric(&format!("scale/rt_p{tag}_p2_rel_err"), v.p2_rel_err[i]);
        }
        sink.metric("scale/ecdf_sup_err", v.ecdf_sup_err);
    }
}

/// Human summary printed by `uwfq scale` and the bench.
pub fn render(o: &ScaleOutcome) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "scale run ({}): {} jobs / {} users in {:.2} s wall\n",
        o.label, o.jobs, o.users, o.wall_s
    ));
    s.push_str(&format!(
        "  throughput   {:.0} jobs/s   {:.2} M task-events/s\n",
        o.jobs_per_s,
        o.task_events_per_s / 1e6
    ));
    s.push_str(&format!(
        "  resident     peak {} in-flight jobs   arenas {} job / {} stage slots\n",
        o.peak_in_flight_jobs, o.arena_job_slots, o.arena_stage_slots
    ));
    s.push_str(&format!(
        "  sim          makespan {:.0} s   utilization {:.2}   users seen {}\n",
        o.makespan_s, o.utilization, o.user_count
    ));
    s.push_str(&format!(
        "  RT           mean {:.3} s   p50/p95/p99 (ECDF) {:.3}/{:.3}/{:.3} s\n",
        o.mean_rt, o.ecdf_q[0], o.ecdf_q[1], o.ecdf_q[2]
    ));
    s.push_str(&format!(
        "  slowdown     mean {:.2}   Jain(user RT) {:.3}\n",
        o.mean_slowdown, o.jain_index
    ));
    if let Some(v) = &o.verify {
        s.push_str(&format!(
            "  accuracy     ECDF q rel err {:.4}/{:.4}/{:.4}   P² {:.4}/{:.4}/{:.4}   sup {:.4}\n",
            v.ecdf_rel_err[0],
            v.ecdf_rel_err[1],
            v.ecdf_rel_err[2],
            v.p2_rel_err[0],
            v.p2_rel_err[1],
            v.p2_rel_err[2],
            v.ecdf_sup_err
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_scale_run_is_bounded_and_accurate() {
        // A deliberately small run (debug-test friendly): outcome counts
        // line up, the backlog stays far below the job count (the
        // O(active) claim at miniature scale), and the verify pass's
        // tolerance check passes.
        let params = ScaleParams {
            users: 50,
            jobs: 800,
            cores: 8,
            target_utilization: 0.8,
            seed: 7,
        };
        let cfg = Config::default().with_cores(8);
        let o = run_scale(&params, &cfg, true);
        assert_eq!(o.jobs, 800);
        assert_eq!(o.user_count, 50);
        assert!(o.task_events > 800);
        assert!(o.peak_in_flight_jobs < 800 / 2, "backlog {} not bounded", o.peak_in_flight_jobs);
        assert!(o.arena_job_slots <= o.peak_in_flight_jobs + 1);
        assert!(o.makespan_s > 0.0 && o.utilization > 0.1);
        let v = o.verify.as_ref().unwrap();
        // The documented tolerances apply at ≥50k samples
        // (tests/scale_accuracy.rs + CI); at 800 jobs order-statistic
        // noise dominates, so only gross sanity is asserted here.
        assert!(v.ecdf_rel_err.iter().all(|&e| e < 0.35), "{:?}", v.ecdf_rel_err);
        assert!(v.p2_rel_err.iter().all(|&e| e < 0.5), "{:?}", v.p2_rel_err);
        assert!(v.ecdf_sup_err < ECDF_SUP_TOL, "sup {}", v.ecdf_sup_err);
        // Exact quantiles are ordered.
        assert!(v.exact_q[0] <= v.exact_q[1] && v.exact_q[1] <= v.exact_q[2]);
    }

    #[test]
    fn scale_idle_map_covers_templates() {
        let cfg = Config::default().with_cores(8);
        let m = scale_idle_map(&cfg);
        assert_eq!(m.len(), 4);
        assert!(m.values().all(|&rt| rt > 0.0));
    }

    #[test]
    fn record_metrics_emits_core_keys() {
        let params = ScaleParams {
            users: 10,
            jobs: 60,
            cores: 8,
            target_utilization: 0.8,
            seed: 3,
        };
        let cfg = Config::default().with_cores(8);
        let o = run_scale(&params, &cfg, false);
        let mut sink = JsonSink::new();
        record_metrics(&o, &mut sink);
        let path = std::env::temp_dir().join("uwfq_scale_metrics_test.json");
        sink.write(path.to_str().unwrap()).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        for key in ["scale/jobs_per_s", "scale/peak_in_flight_jobs", "scale/rt_p95_ecdf_s"] {
            assert!(text.contains(key), "missing {key}");
        }
        std::fs::remove_file(path).ok();
    }
}
