//! Table 1 (micro scenarios 1–2) and Table 2 (macro benchmark)
//! regeneration (§5.2.2, §5.3.1).
//!
//! Both tables are grids of independent simulation cells (UJF reference
//! first, then the non-UJF paper rows — see [`super::paper_cells`]) run
//! through the [`crate::sweep`] engine; the fairness columns are computed
//! at merge time from the UJF cell of the same partitioning scheme, so
//! parallel and sequential execution render byte-identical tables.

use super::{fmt1, fmt2, render_table, run_one_in, paper_cells};
use crate::config::Config;
use crate::metrics::fairness::{fairness_vs_ujf, DvrDenominator, FairnessMetrics};
use crate::metrics::report::RunMetrics;
use crate::sched::PolicyKind;
use crate::sweep::Sweep;
use crate::util::csvout::Csv;
use crate::workload::registry::{builtin_workload, ScenarioSpec};
use crate::workload::{UserClass, Workload};

/// One scheduler row of Table 1.
#[derive(Clone, Debug)]
pub struct Table1Row {
    pub label: String,
    pub rt_avg: f64,
    pub rt_worst10: f64,
    pub sl_avg: f64,
    pub sl_worst10: f64,
    /// Scenario 1: (frequent, infrequent) mean RT. Scenario 2: unused.
    pub class_rt: Option<(f64, f64)>,
    /// Scenario 2: (first user, last user) mean RT. Scenario 1: unused.
    pub first_last_rt: Option<(f64, f64)>,
    /// None for the UJF reference row.
    pub fairness: Option<FairnessMetrics>,
    pub metrics: RunMetrics,
}

/// All rows of one scenario.
pub struct Table1Scenario {
    pub name: String,
    pub rows: Vec<Table1Row>,
}

/// Run one scenario across the paper's four schedulers (one 4-cell grid
/// on the sweep engine).
pub fn table1_scenario(
    workload: &Workload,
    base: &Config,
    scenario1_classes: bool,
    sweep: &Sweep,
) -> Table1Scenario {
    let cells = paper_cells(base);
    let metrics = sweep.run(&cells, |ctx, cfg| run_one_in(ctx, cfg, workload));
    table1_rows(workload, metrics, scenario1_classes)
}

/// Merge one scenario's cell results (UJF reference first, then the
/// non-UJF paper rows) into table rows — runs after the sweep, in
/// deterministic cell order. Consumes the results; only the UJF
/// reference (genuinely used twice) is cloned.
fn table1_rows(
    workload: &Workload,
    metrics: Vec<RunMetrics>,
    scenario1_classes: bool,
) -> Table1Scenario {
    let mut it = metrics.into_iter();
    let ujf = it.next().expect("UJF reference cell");
    let mut rows = Vec::new();
    for policy in PolicyKind::PAPER {
        let m = if policy == PolicyKind::Ujf {
            ujf.clone()
        } else {
            it.next().expect("paper row cell")
        };
        let fairness = (policy != PolicyKind::Ujf)
            .then(|| fairness_vs_ujf(&m, &ujf, DvrDenominator::GreaterThanZero));
        let class_rt = scenario1_classes.then(|| {
            (
                m.mean_rt_by_class(UserClass::Frequent),
                m.mean_rt_by_class(UserClass::Infrequent),
            )
        });
        let first_last_rt = (!scenario1_classes).then(|| {
            let users = m.users();
            (
                m.mean_rt_of_user(*users.first().unwrap()),
                m.mean_rt_of_user(*users.last().unwrap()),
            )
        });
        rows.push(Table1Row {
            label: m.label.clone(),
            rt_avg: m.mean_rt(),
            rt_worst10: m.worst10_rt(),
            sl_avg: m.mean_slowdown(),
            sl_worst10: m.worst10_slowdown(),
            class_rt,
            first_last_rt,
            fairness,
            metrics: m,
        });
    }
    Table1Scenario {
        name: workload.name.clone(),
        rows,
    }
}

/// The Table-1 workloads, referenced by registry name (paper defaults) —
/// the scenario list is data, not code.
pub const TABLE1_SCENARIOS: [&str; 2] = ["scenario1", "scenario2"];

/// Full Table 1: both micro scenarios as one combined 8-cell grid, so a
/// multi-worker sweep overlaps cells across scenarios.
pub fn table1(seed: u64, base: &Config, sweep: &Sweep) -> (Table1Scenario, Table1Scenario) {
    let s1 = builtin_workload(TABLE1_SCENARIOS[0], seed);
    let s2 = builtin_workload(TABLE1_SCENARIOS[1], seed);
    let cfgs = paper_cells(base);
    let cells: Vec<(&Workload, &Config)> = [&s1, &s2]
        .into_iter()
        .flat_map(|w| cfgs.iter().map(move |c| (w, c)))
        .collect();
    let mut metrics = sweep.run(&cells, |ctx, &(w, cfg)| run_one_in(ctx, cfg, w));
    let m2 = metrics.split_off(cfgs.len());
    (table1_rows(&s1, metrics, true), table1_rows(&s2, m2, false))
}

/// Text rendering in the paper's layout.
pub fn render_table1(s: &Table1Scenario) -> String {
    let scenario1 = s.rows[0].class_rt.is_some();
    let (c1, c2) = if scenario1 {
        ("Freq.", "Infreq.")
    } else {
        ("First", "Last")
    };
    let header = vec![
        "Scheduler", "RTavg", "RTw10%", "SLavg", "SLw10%", c1, c2, "DVR", "Viol#", "DSR",
        "Slack#",
    ];
    let rows: Vec<Vec<String>> = s
        .rows
        .iter()
        .map(|r| {
            let (a, b) = r.class_rt.or(r.first_last_rt).unwrap_or((0.0, 0.0));
            let (dvr, viol, dsr, slack) = match &r.fairness {
                Some(f) => (
                    fmt2(f.dvr),
                    f.violations.to_string(),
                    fmt2(f.dsr),
                    f.slacks.to_string(),
                ),
                None => ("-".into(), "-".into(), "-".into(), "-".into()),
            };
            vec![
                r.label.clone(),
                fmt1(r.rt_avg),
                fmt1(r.rt_worst10),
                fmt1(r.sl_avg),
                fmt1(r.sl_worst10),
                fmt1(a),
                fmt1(b),
                dvr,
                viol,
                dsr,
                slack,
            ]
        })
        .collect();
    format!("== Table 1 / {} ==\n{}", s.name, render_table(&header, &rows))
}

/// Write a Table 1 scenario as CSV.
pub fn write_table1_csv(path: &str, s: &Table1Scenario) -> std::io::Result<()> {
    let mut csv = Csv::create(
        path,
        &[
            "scheduler", "rt_avg", "rt_worst10", "sl_avg", "sl_worst10", "class_a_rt",
            "class_b_rt", "dvr", "violations", "dsr", "slacks",
        ],
    )?;
    for r in &s.rows {
        let (a, b) = r.class_rt.or(r.first_last_rt).unwrap_or((0.0, 0.0));
        let (dvr, viol, dsr, slack) = match &r.fairness {
            Some(f) => (f.dvr, f.violations as f64, f.dsr, f.slacks as f64),
            None => (f64::NAN, f64::NAN, f64::NAN, f64::NAN),
        };
        csv.row(&[
            r.label.clone(),
            format!("{:.4}", r.rt_avg),
            format!("{:.4}", r.rt_worst10),
            format!("{:.4}", r.sl_avg),
            format!("{:.4}", r.sl_worst10),
            format!("{a:.4}"),
            format!("{b:.4}"),
            format!("{dvr:.4}"),
            format!("{viol}"),
            format!("{dsr:.4}"),
            format!("{slack}"),
        ])?;
    }
    csv.finish()
}

// ---------------------------------------------------------------------------
// Table 2 — macro benchmark
// ---------------------------------------------------------------------------

#[derive(Clone, Debug)]
pub struct Table2Row {
    pub label: String,
    /// Benchmark wall time (makespan), the paper's "Runtime" column.
    pub runtime: f64,
    pub rt_avg: f64,
    pub rt_0_80: f64,
    pub rt_80_95: f64,
    pub rt_95_100: f64,
    pub fairness: Option<FairnessMetrics>,
    pub metrics: RunMetrics,
}

pub struct Table2 {
    pub rows: Vec<Table2Row>,
}

/// Run the macro benchmark: 4 schedulers × {default, runtime} partitioning
/// (8 rows, as in the paper) as one 8-cell grid. DVR/DSR compare against
/// UJF *with the same partitioning* (§5.1.2): each scheme group's UJF
/// reference is its cell 0, consumed at merge time.
pub fn table2(workload: &Workload, base: &Config, sweep: &Sweep) -> Table2 {
    let schemes = super::TABLE_SCHEMES;
    let cells: Vec<Config> = schemes
        .iter()
        .flat_map(|&s| paper_cells(&base.clone().with_scheme(s)))
        .collect();
    let metrics = sweep.run(&cells, |ctx, cfg| run_one_in(ctx, cfg, workload));

    // Consume results scheme group by scheme group (UJF reference first
    // in each); only the reference, used by every row's fairness
    // columns, is cloned.
    let mut it = metrics.into_iter();
    let mut rows = Vec::new();
    for _scheme in &schemes {
        let ujf = it.next().expect("UJF reference cell");
        for policy in PolicyKind::PAPER {
            let m = if policy == PolicyKind::Ujf {
                ujf.clone()
            } else {
                it.next().expect("paper row cell")
            };
            let fairness = (policy != PolicyKind::Ujf)
                .then(|| fairness_vs_ujf(&m, &ujf, DvrDenominator::GreaterThanZero));
            rows.push(Table2Row {
                label: m.label.clone(),
                runtime: m.makespan_s,
                rt_avg: m.mean_rt(),
                rt_0_80: m.mean_rt_band(0.0, 80.0),
                rt_80_95: m.mean_rt_band(80.0, 95.0),
                rt_95_100: m.mean_rt_band(95.0, 100.0),
                fairness,
                metrics: m,
            });
        }
    }
    Table2 { rows }
}

const TABLE2_HEADER: [&str; 10] = [
    "Scheduler", "Runtime", "RTavg", "0-80%", "80-95%", "95-100%", "DVR", "Viol#", "DSR",
    "Slack#",
];

fn table2_row_cells(rows: &[Table2Row]) -> Vec<Vec<String>> {
    rows.iter()
        .map(|r| {
            let (dvr, viol, dsr, slack) = match &r.fairness {
                Some(f) => (
                    fmt2(f.dvr),
                    f.violations.to_string(),
                    fmt2(f.dsr),
                    f.slacks.to_string(),
                ),
                None => ("-".into(), "-".into(), "-".into(), "-".into()),
            };
            vec![
                r.label.clone(),
                fmt1(r.runtime),
                fmt2(r.rt_avg),
                fmt2(r.rt_0_80),
                fmt2(r.rt_80_95),
                fmt1(r.rt_95_100),
                dvr,
                viol,
                dsr,
                slack,
            ]
        })
        .collect()
}

pub fn render_table2(t: &Table2) -> String {
    format!(
        "== Table 2 / macro ==\n{}",
        render_table(&TABLE2_HEADER, &table2_row_cells(&t.rows))
    )
}

fn write_table2_rows_csv(path: &str, rows: &[Table2Row]) -> std::io::Result<()> {
    let mut csv = Csv::create(
        path,
        &[
            "scheduler", "runtime", "rt_avg", "rt_0_80", "rt_80_95", "rt_95_100", "dvr",
            "violations", "dsr", "slacks",
        ],
    )?;
    for r in rows {
        let (dvr, viol, dsr, slack) = match &r.fairness {
            Some(f) => (f.dvr, f.violations as f64, f.dsr, f.slacks as f64),
            None => (f64::NAN, f64::NAN, f64::NAN, f64::NAN),
        };
        csv.row(&[
            r.label.clone(),
            format!("{:.4}", r.runtime),
            format!("{:.4}", r.rt_avg),
            format!("{:.4}", r.rt_0_80),
            format!("{:.4}", r.rt_80_95),
            format!("{:.4}", r.rt_95_100),
            format!("{dvr:.4}"),
            format!("{viol}"),
            format!("{dsr:.4}"),
            format!("{slack}"),
        ])?;
    }
    csv.finish()
}

pub fn write_table2_csv(path: &str, t: &Table2) -> std::io::Result<()> {
    write_table2_rows_csv(path, &t.rows)
}

// ---------------------------------------------------------------------------
// Generic scenario grid — any registry entry, zero scenario-specific code
// ---------------------------------------------------------------------------

/// The generic registry grid for one scenario: **all five** policies ×
/// both partitioning schemes, with DVR/DSR against the UJF reference of
/// the same scheme (§5.1.2). This is the grid every newly registered
/// scenario gets for free (`uwfq sweep --scenario NAME`).
pub struct ScenarioGrid {
    pub scenario: String,
    pub rows: Vec<Table2Row>,
}

pub fn scenario_grid(
    spec: &ScenarioSpec,
    base: &Config,
    sweep: &Sweep,
) -> Result<ScenarioGrid, String> {
    let w = spec.workload(base.seed)?;
    let schemes = super::TABLE_SCHEMES;
    // Cell 0 of each scheme group is the UJF reference; the remaining
    // cells cover every non-UJF policy (the UJF row reuses the
    // reference), mirroring the Table-2 consumption order.
    let mut cells: Vec<Config> = Vec::new();
    for &scheme in &schemes {
        let b = base.clone().with_scheme(scheme);
        cells.push(b.clone().with_policy(PolicyKind::Ujf));
        for &p in PolicyKind::ALL.iter().filter(|&&p| p != PolicyKind::Ujf) {
            cells.push(b.clone().with_policy(p));
        }
    }
    let metrics = sweep.run(&cells, |ctx, cfg| run_one_in(ctx, cfg, &w));

    let mut it = metrics.into_iter();
    let mut rows = Vec::new();
    for _scheme in &schemes {
        let ujf = it.next().expect("UJF reference cell");
        for policy in PolicyKind::ALL {
            let m = if policy == PolicyKind::Ujf {
                ujf.clone()
            } else {
                it.next().expect("scenario grid cell")
            };
            let fairness = (policy != PolicyKind::Ujf)
                .then(|| fairness_vs_ujf(&m, &ujf, DvrDenominator::GreaterThanZero));
            rows.push(Table2Row {
                label: m.label.clone(),
                runtime: m.makespan_s,
                rt_avg: m.mean_rt(),
                rt_0_80: m.mean_rt_band(0.0, 80.0),
                rt_80_95: m.mean_rt_band(80.0, 95.0),
                rt_95_100: m.mean_rt_band(95.0, 100.0),
                fairness,
                metrics: m,
            });
        }
    }
    Ok(ScenarioGrid {
        scenario: w.name.clone(),
        rows,
    })
}

pub fn render_scenario_grid(g: &ScenarioGrid) -> String {
    format!(
        "== scenario grid / {} ==\n{}",
        g.scenario,
        render_table(&TABLE2_HEADER, &table2_row_cells(&g.rows))
    )
}

pub fn write_scenario_grid_csv(path: &str, g: &ScenarioGrid) -> std::io::Result<()> {
    write_table2_rows_csv(path, &g.rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_base() -> Config {
        Config::default().with_cores(8)
    }

    fn small_scenario2() -> Workload {
        crate::workload::test_scenario2(1, 5, 0.5)
    }

    fn small_gtrace() -> ScenarioSpec {
        ScenarioSpec::new("gtrace")
            .with("window_s", "60")
            .with("users", "6")
            .with("heavy_users", "2")
            .with("cores", "8")
    }

    #[test]
    fn table1_scenario2_small_runs() {
        let w = small_scenario2();
        let s = table1_scenario(&w, &small_base(), false, &Sweep::seq());
        assert_eq!(s.rows.len(), 4);
        // UJF row has no fairness metrics; others do.
        assert!(s.rows.iter().any(|r| r.fairness.is_none()));
        assert_eq!(s.rows.iter().filter(|r| r.fairness.is_some()).count(), 3);
        for r in &s.rows {
            assert!(r.rt_avg > 0.0);
            assert!(r.rt_worst10 >= r.rt_avg);
            assert!(r.first_last_rt.is_some());
        }
        let text = render_table1(&s);
        assert!(text.contains("UWFQ") && text.contains("First"));
    }

    #[test]
    fn table2_small_macro_runs() {
        let w = small_gtrace().workload(5).unwrap();
        let t = table2(&w, &small_base(), &Sweep::seq());
        assert_eq!(t.rows.len(), 8);
        // -P rows present.
        assert!(t.rows.iter().any(|r| r.label == "UWFQ-P"));
        let text = render_table2(&t);
        assert!(text.contains("Fair-P"));
        for r in &t.rows {
            assert!(r.runtime > 0.0, "{}", r.label);
        }
    }

    #[test]
    fn scenario_grid_covers_all_policies_and_schemes() {
        // The generic registry grid: any entry, all five policies × both
        // partitioners, no scenario-specific bench code.
        let spec = ScenarioSpec::new("bursty")
            .with("duration_s", "60")
            .with("cycle_s", "30");
        let g = scenario_grid(&spec, &small_base(), &Sweep::seq()).unwrap();
        assert_eq!(g.scenario, "bursty");
        assert_eq!(g.rows.len(), 2 * PolicyKind::ALL.len());
        for label in ["FIFO", "UWFQ", "FIFO-P", "UWFQ-P", "UJF", "UJF-P"] {
            assert!(g.rows.iter().any(|r| r.label == label), "missing {label}");
        }
        // UJF rows carry no fairness columns; all others do.
        assert_eq!(g.rows.iter().filter(|r| r.fairness.is_none()).count(), 2);
        // Parallel == sequential on the generic grid too.
        let par = scenario_grid(&spec, &small_base(), &Sweep::new(3)).unwrap();
        assert_eq!(render_scenario_grid(&g), render_scenario_grid(&par));
        // Unknown scenarios error with the registry's name list.
        let err = scenario_grid(&ScenarioSpec::new("zzz"), &small_base(), &Sweep::seq())
            .unwrap_err();
        assert!(err.contains("unknown scenario"), "{err}");
    }

    #[test]
    fn table1_parallel_rows_match_sequential() {
        // Grid-level determinism at the unit scale: the 8-cell combined
        // Table 1 grid renders identically at 1 and 3 workers.
        let seq = table1(9, &small_base(), &Sweep::seq());
        let par = table1(9, &small_base(), &Sweep::new(3));
        assert_eq!(render_table1(&seq.0), render_table1(&par.0));
        assert_eq!(render_table1(&seq.1), render_table1(&par.1));
    }

    #[test]
    fn csv_outputs_written() {
        let dir = std::env::temp_dir().join("uwfq_tables_test");
        std::fs::create_dir_all(&dir).unwrap();
        let w = small_scenario2();
        let s = table1_scenario(&w, &small_base(), false, &Sweep::seq());
        let p = dir.join("t1.csv");
        write_table1_csv(p.to_str().unwrap(), &s).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert_eq!(text.lines().count(), 5);
        std::fs::remove_dir_all(dir).ok();
    }
}
