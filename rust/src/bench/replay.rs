//! The trace-replay harness behind `uwfq replay` and `benches/replay.rs`:
//! stream a trace file through the one-pass shaper and the simulator with
//! bounded-memory metrics, and report throughput plus the resident-state
//! counters that back the O(warmup + in-flight) contract.
//!
//! Memory model: the reader holds one chunk, the shaper holds at most
//! `warmup` rows (drained once the factors freeze), the engine holds the
//! in-flight backlog, and the metrics sink is O(users + bins). No per-job
//! state survives a completion — a million-row trace replays without ever
//! materializing its job list.
//!
//! Slowdown columns are deliberately absent: trace jobs carry unique
//! names, so per-template idle-response denominators do not exist on the
//! streaming path (the exact grids, `uwfq sweep --scenario trace`, still
//! compute them).

use std::collections::HashMap;
use std::time::Instant;

use crate::config::Config;
use crate::core::SchedCore;
use crate::metrics::streaming::StreamingRunMetrics;
use crate::sim;
use crate::util::benchkit::JsonSink;
use crate::workload::traceio::{self, TraceParams};

/// The tracked response-time quantiles (ECDF-inverted).
pub const QUANTILES: [f64; 3] = [0.50, 0.95, 0.99];

/// Everything one replay run produces.
pub struct ReplayOutcome {
    pub label: String,
    /// Data rows in the trace file.
    pub rows: u64,
    /// Rows dropped by the runtime-tail filter.
    pub rows_dropped: u64,
    pub jobs: u64,
    pub users: usize,
    pub wall_s: f64,
    pub jobs_per_s: f64,
    pub task_events: u64,
    pub task_events_per_s: f64,
    /// Peak concurrently in-flight jobs (the O(active) bound).
    pub peak_in_flight_jobs: usize,
    /// Peak shaper-buffered rows (≤ warmup by construction).
    pub max_buffered_rows: usize,
    pub heavy_scale: f64,
    pub util_scale: f64,
    pub makespan_s: f64,
    pub utilization: f64,
    pub mean_rt: f64,
    pub jain_index: f64,
    /// ECDF-inverted response-time quantiles.
    pub ecdf_q: [f64; 3],
}

/// Run one streaming replay. The trace is fully validated by the class
/// scan before the timed pass, so malformed rows surface as `Err`, not
/// panics.
pub fn run_replay(tp: &TraceParams, cfg: &Config) -> Result<ReplayOutcome, String> {
    let (_classes, rows) = traceio::scan_user_classes(&tp.path, tp.format)?;
    let mut stream = traceio::open_trace(tp)?;
    let mut sink = StreamingRunMetrics::new(&cfg.label(), HashMap::new());
    let mut core = SchedCore::from_config(cfg.clone());
    let t0 = Instant::now();
    let summary = sim::simulate_stream_into(&mut core, &mut stream, &mut sink);
    let wall_s = t0.elapsed().as_secs_f64().max(1e-9);
    let stats = stream.shape_stats();

    Ok(ReplayOutcome {
        label: summary.label,
        rows,
        rows_dropped: stats.rows_dropped,
        jobs: summary.jobs_completed,
        users: sink.user_count(),
        wall_s,
        jobs_per_s: summary.jobs_completed as f64 / wall_s,
        task_events: summary.task_events,
        task_events_per_s: summary.task_events as f64 / wall_s,
        peak_in_flight_jobs: summary.peak_in_flight_jobs,
        max_buffered_rows: stats.max_buffered,
        heavy_scale: stats.heavy_scale,
        util_scale: stats.util_scale,
        makespan_s: summary.makespan_s,
        utilization: summary.utilization,
        mean_rt: sink.mean_rt(),
        jain_index: sink.jain_index_user_rt(),
        ecdf_q: QUANTILES.map(|p| sink.rt_quantile_ecdf(p)),
    })
}

/// Record a replay outcome into a benchkit sink (`BENCH_replay.json`).
pub fn record_metrics(o: &ReplayOutcome, sink: &mut JsonSink) {
    sink.metric("replay/rows", o.rows as f64);
    sink.metric("replay/rows_dropped", o.rows_dropped as f64);
    sink.metric("replay/jobs", o.jobs as f64);
    sink.metric("replay/users", o.users as f64);
    sink.metric("replay/wall_s", o.wall_s);
    sink.metric("replay/jobs_per_s", o.jobs_per_s);
    sink.metric("replay/task_events", o.task_events as f64);
    sink.metric("replay/task_events_per_s", o.task_events_per_s);
    sink.metric("replay/peak_in_flight_jobs", o.peak_in_flight_jobs as f64);
    sink.metric("replay/max_buffered_rows", o.max_buffered_rows as f64);
    sink.metric("replay/heavy_scale", o.heavy_scale);
    sink.metric("replay/util_scale", o.util_scale);
    sink.metric("replay/makespan_s", o.makespan_s);
    sink.metric("replay/utilization", o.utilization);
    sink.metric("replay/mean_rt_s", o.mean_rt);
    sink.metric("replay/jain_index_user_rt", o.jain_index);
    for (i, p) in QUANTILES.iter().enumerate() {
        let tag = (p * 100.0).round() as u32;
        sink.metric(&format!("replay/rt_p{tag}_ecdf_s"), o.ecdf_q[i]);
    }
}

/// Human summary printed by `uwfq replay` and the bench.
pub fn render(o: &ReplayOutcome) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "trace replay ({}): {} rows → {} jobs ({} filtered) / {} users in {:.2} s wall\n",
        o.label, o.rows, o.jobs, o.rows_dropped, o.users, o.wall_s
    ));
    s.push_str(&format!(
        "  throughput   {:.0} jobs/s   {:.2} M task-events/s\n",
        o.jobs_per_s,
        o.task_events_per_s / 1e6
    ));
    s.push_str(&format!(
        "  resident     peak {} in-flight jobs   peak {} buffered rows\n",
        o.peak_in_flight_jobs, o.max_buffered_rows
    ));
    s.push_str(&format!(
        "  shaping      heavy ×{:.3}   utilization ×{:.3}\n",
        o.heavy_scale, o.util_scale
    ));
    s.push_str(&format!(
        "  sim          makespan {:.0} s   utilization {:.2}\n",
        o.makespan_s, o.utilization
    ));
    s.push_str(&format!(
        "  RT           mean {:.3} s   p50/p95/p99 (ECDF) {:.3}/{:.3}/{:.3} s   Jain {:.3}\n",
        o.mean_rt, o.ecdf_q[0], o.ecdf_q[1], o.ecdf_q[2], o.jain_index
    ));
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::gtrace::GtraceParams;
    use crate::workload::traceio::{writer, ShapeParams};

    #[test]
    fn small_replay_run_is_bounded_and_complete() {
        let dir = std::env::temp_dir().join(format!("uwfq_breplay_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.csv").to_str().unwrap().to_string();
        let gp = GtraceParams {
            window_s: 120.0,
            users: 8,
            heavy_users: 2,
            cores: 8,
            target_utilization: 0.7,
            ..GtraceParams::default()
        };
        let rows = writer::write_synthetic(&path, 3, &gp).unwrap();
        let tp = TraceParams {
            path: path.clone(),
            shaping: ShapeParams {
                warmup: 32,
                cores: 8,
                target_utilization: 0.7,
                ..ShapeParams::default()
            },
            ..TraceParams::default()
        };
        let cfg = Config::default().with_cores(8);
        let o = run_replay(&tp, &cfg).unwrap();
        assert_eq!(o.rows, rows);
        assert_eq!(o.jobs + o.rows_dropped, rows);
        assert!(o.jobs > 0 && o.task_events > o.jobs);
        assert!(o.max_buffered_rows <= 32);
        assert!(o.peak_in_flight_jobs < o.jobs as usize);
        assert!(o.makespan_s > 0.0 && o.mean_rt > 0.0);

        let mut sink = JsonSink::new();
        record_metrics(&o, &mut sink);
        let jpath = dir.join("m.json");
        sink.write(jpath.to_str().unwrap()).unwrap();
        let text = std::fs::read_to_string(&jpath).unwrap();
        for key in [
            "replay/jobs_per_s",
            "replay/peak_in_flight_jobs",
            "replay/max_buffered_rows",
            "replay/rt_p95_ecdf_s",
        ] {
            assert!(text.contains(key), "missing {key}");
        }
        assert!(render(&o).contains("trace replay"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn replay_surfaces_trace_errors() {
        let tp = TraceParams {
            path: "/nonexistent/replay.csv".into(),
            ..TraceParams::default()
        };
        let err = run_replay(&tp, &Config::default()).unwrap_err();
        assert!(err.contains("/nonexistent/replay.csv"), "{err}");
    }
}
