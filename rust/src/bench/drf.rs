//! Multi-resource fairness bench (`uwfq drf`, `BENCH_drf.json`): the
//! seven-policy grid on a mixed-demand workload — half the users
//! CPU-heavy, half memory-heavy — plus the UWFQ-vs-BoPF burst-tolerance
//! ablation on the `bursty` scenario.
//!
//! The mixed grid answers whether DRF's dominant-share ordering moves
//! the per-dimension goodput split where slot-count policies cannot see
//! it; the burst ablation sweeps the BoPF budget and reads off how much
//! the bursty users' response time improves before the steady users
//! start paying for it.

use crate::config::Config;
use crate::core::job::JobSpec;
use crate::core::task::ResourceVec;
use crate::core::SchedCore;
use crate::sched::PolicyKind;
use crate::sweep::Sweep;
use crate::util::benchkit::JsonSink;
use crate::workload::{ScenarioSpec, UserClass, Workload};

/// One policy row of the mixed-demand grid.
pub struct MixCell {
    pub label: String,
    pub mean_rt: f64,
    pub worst10_rt: f64,
    /// Jain fairness index over per-user mean response times.
    pub jain: f64,
    pub utilization: f64,
    /// Useful core-seconds delivered per resource dimension (from the
    /// engine's per-dimension ledgers; equal for unit-vector runs).
    pub cpu_core_s: f64,
    pub mem_core_s: f64,
}

/// One arm of the burst-tolerance ablation.
pub struct BurstCell {
    /// Arm name (`uwfq`, `fair`, `bopf_b2`, ...).
    pub arm: String,
    pub label: String,
    /// Mean RT over the bursty (Frequent) users' jobs.
    pub burst_rt: f64,
    /// Mean RT over the steady (Infrequent) users' jobs.
    pub steady_rt: f64,
    pub mean_rt: f64,
    pub jain: f64,
}

pub struct DrfBench {
    pub mix: Vec<MixCell>,
    pub burst: Vec<BurstCell>,
    pub mix_jobs: usize,
    pub mix_users: usize,
    pub burst_jobs: usize,
}

/// BoPF budgets swept in the burst ablation (core-seconds of
/// at-priority work per burst).
const BOPF_BUDGETS: [f64; 3] = [2.0, 10.0, 50.0];

/// The mixed-demand workload: the fault-bench shape (same-instant
/// bursts, skewed per-user activity) with a demand profile per user —
/// even users CPU-dominant, odd users memory-dominant. Every vector
/// fits a unit slot, so only the multi-resource policies can tell the
/// profiles apart.
fn mixed_workload(quick: bool, seed: u64) -> Vec<JobSpec> {
    let n = if quick { 48 } else { 160 };
    (0..n)
        .map(|i| {
            let user = ((i * 7 + seed as usize) % 8) as u32;
            let arrival_s = if i % 5 == 0 {
                (i / 5) as f64 * 0.3
            } else {
                i as f64 * 0.06
            };
            let compute = 0.3 + ((i * 13) % 9) as f64 * 0.35;
            let demand = if user % 2 == 0 {
                ResourceVec::new(1.0, 0.3)
            } else {
                ResourceVec::new(0.35, 1.0)
            };
            JobSpec::three_phase(
                user,
                &format!("d{i}"),
                crate::s_to_us(arrival_s),
                compute,
                (32 + (i as u64 % 5) * 32) << 20,
                4,
                None,
            )
            .with_demand(demand)
        })
        .collect()
}

/// Jain's fairness index over per-user mean response times.
fn jain_over_user_rt(completed: &[crate::core::dag::CompletedJob]) -> f64 {
    let mut per_user: std::collections::BTreeMap<u32, (f64, u64)> = Default::default();
    for c in completed {
        let e = per_user.entry(c.user).or_insert((0.0, 0));
        e.0 += c.response_time();
        e.1 += 1;
    }
    let means: Vec<f64> = per_user.values().map(|&(s, n)| s / n as f64).collect();
    let sum: f64 = means.iter().sum();
    let sq: f64 = means.iter().map(|x| x * x).sum();
    if sq > 0.0 {
        sum * sum / (means.len() as f64 * sq)
    } else {
        1.0
    }
}

fn mean_rts(completed: &[crate::core::dag::CompletedJob]) -> (f64, f64) {
    let mut rts: Vec<f64> = completed.iter().map(|c| c.response_time()).collect();
    rts.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = rts.iter().sum::<f64>() / rts.len().max(1) as f64;
    let k = (rts.len() / 10).max(1);
    let worst10 = rts[rts.len() - k..].iter().sum::<f64>() / k as f64;
    (mean, worst10)
}

/// The bursty-scenario workload of the ablation: multi-resource burst
/// users (`mem_frac` below 1) so the BoPF arms exercise the vector
/// path, shrunk like `--quick` scenario overrides when `quick`.
fn burst_workload(quick: bool, seed: u64) -> Workload {
    let mut spec = ScenarioSpec::new("bursty").with("mem_frac", "0.5");
    if quick {
        spec = spec.with("duration_s", "60").with("cycle_s", "30");
    }
    spec.workload(seed)
        .unwrap_or_else(|e| panic!("bursty ablation workload: {e}"))
}

/// Run both grids (policies × mixed demand; burst arms) through the
/// sweep engine.
pub fn run_drf(base: &Config, quick: bool, swp: &Sweep) -> DrfBench {
    let jobs = mixed_workload(quick, base.seed);
    let mix_users = {
        let mut u: Vec<u32> = jobs.iter().map(|j| j.user).collect();
        u.sort_unstable();
        u.dedup();
        u.len()
    };
    let mix_cfgs: Vec<Config> = PolicyKind::ALL
        .iter()
        .map(|&p| base.clone().with_policy(p))
        .collect();
    // Cells build their own engine (not the memoized sim context) so the
    // per-dimension resource ledgers stay readable after the run.
    let mix = swp.run(&mix_cfgs, |_ctx, cfg| {
        let mut core = SchedCore::from_config(cfg.clone());
        let report = crate::sim::simulate_into(&mut core, jobs.clone());
        let (mean_rt, worst10_rt) = mean_rts(&report.completed);
        let [gc, gm] = core.resource_good_mmus();
        MixCell {
            label: report.label.clone(),
            mean_rt,
            worst10_rt,
            jain: jain_over_user_rt(&report.completed),
            utilization: report.utilization,
            cpu_core_s: gc as f64 / 1e9,
            mem_core_s: gm as f64 / 1e9,
        }
    });

    let w = burst_workload(quick, base.seed);
    let mut burst_cfgs: Vec<(String, Config)> = vec![
        ("uwfq".into(), base.clone().with_policy(PolicyKind::Uwfq)),
        ("fair".into(), base.clone().with_policy(PolicyKind::Fair)),
    ];
    for b in BOPF_BUDGETS {
        let mut cfg = base.clone().with_policy(PolicyKind::Bopf);
        cfg.bopf_burst_rsec = b;
        burst_cfgs.push((format!("bopf_b{b:.0}"), cfg));
    }
    let burst = swp.run(&burst_cfgs, |ctx, (arm, cfg)| {
        let report = ctx.simulate(cfg, w.jobs.clone());
        let (mean_rt, _) = mean_rts(&report.completed);
        let mut cls: [(f64, u64); 2] = [(0.0, 0); 2]; // [burst, steady]
        for c in &report.completed {
            // `bursty` classifies users as Frequent (bursty) or
            // Infrequent (steady) only.
            let i = if w.user_class[&c.user] == UserClass::Frequent {
                0
            } else {
                1
            };
            cls[i].0 += c.response_time();
            cls[i].1 += 1;
        }
        BurstCell {
            arm: arm.clone(),
            label: report.label.clone(),
            burst_rt: cls[0].0 / cls[0].1.max(1) as f64,
            steady_rt: cls[1].0 / cls[1].1.max(1) as f64,
            mean_rt,
            jain: jain_over_user_rt(&report.completed),
        }
    });

    DrfBench {
        mix,
        burst,
        mix_jobs: jobs.len(),
        mix_users,
        burst_jobs: w.jobs.len(),
    }
}

pub fn render(b: &DrfBench) -> String {
    let header = [
        "policy", "RT avg", "RT w10", "Jain", "util", "cpu core-s", "mem core-s",
    ];
    let rows: Vec<Vec<String>> = b
        .mix
        .iter()
        .map(|c| {
            vec![
                c.label.clone(),
                super::fmt2(c.mean_rt),
                super::fmt2(c.worst10_rt),
                format!("{:.3}", c.jain),
                super::fmt2(c.utilization),
                super::fmt1(c.cpu_core_s),
                super::fmt1(c.mem_core_s),
            ]
        })
        .collect();
    let bheader = ["arm", "policy", "RT burst", "RT steady", "RT avg", "Jain"];
    let brows: Vec<Vec<String>> = b
        .burst
        .iter()
        .map(|c| {
            vec![
                c.arm.clone(),
                c.label.clone(),
                super::fmt2(c.burst_rt),
                super::fmt2(c.steady_rt),
                super::fmt2(c.mean_rt),
                format!("{:.3}", c.jain),
            ]
        })
        .collect();
    format!(
        "== mixed-demand grid ({} jobs / {} users) ==\n{}\n\
         == burst tolerance (bursty, {} jobs) ==\n{}",
        b.mix_jobs,
        b.mix_users,
        super::render_table(&header, &rows),
        b.burst_jobs,
        super::render_table(&bheader, &brows)
    )
}

pub fn record_metrics(b: &DrfBench, sink: &mut JsonSink) {
    for c in &b.mix {
        let p = format!("drf/mix/{}", c.label);
        sink.metric(&format!("{p}/mean_rt_s"), c.mean_rt);
        sink.metric(&format!("{p}/worst10_rt_s"), c.worst10_rt);
        sink.metric(&format!("{p}/jain_user_rt"), c.jain);
        sink.metric(&format!("{p}/utilization"), c.utilization);
        sink.metric(&format!("{p}/good_cpu_core_s"), c.cpu_core_s);
        sink.metric(&format!("{p}/good_mem_core_s"), c.mem_core_s);
    }
    for c in &b.burst {
        let p = format!("drf/burst/{}", c.arm);
        sink.metric(&format!("{p}/burst_rt_s"), c.burst_rt);
        sink.metric(&format!("{p}/steady_rt_s"), c.steady_rt);
        sink.metric(&format!("{p}/mean_rt_s"), c.mean_rt);
        sink.metric(&format!("{p}/jain_user_rt"), c.jain);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_grids_run_all_policies_and_arms() {
        let mut base = Config::default();
        base.cores = 8;
        let b = run_drf(&base, true, &Sweep::seq());
        assert_eq!(b.mix.len(), PolicyKind::ALL.len());
        for c in &b.mix {
            assert!(c.mean_rt > 0.0, "{}", c.label);
            assert!(c.jain > 0.0 && c.jain <= 1.0 + 1e-12, "{}", c.label);
            // Mixed demand: memory goodput must lag CPU goodput (every
            // profile has mem ≤ cpu or cpu < 1 with full mem, and the
            // mixture is CPU-heavier overall under this seed's user mix).
            assert!(c.cpu_core_s > 0.0 && c.mem_core_s > 0.0, "{}", c.label);
            assert!(c.cpu_core_s != c.mem_core_s, "{}: unit-vector ledgers?", c.label);
        }
        // The burst ablation covers both baselines and every budget.
        assert_eq!(b.burst.len(), 2 + BOPF_BUDGETS.len());
        assert!(b.burst.iter().any(|c| c.arm == "uwfq"));
        assert!(b.burst.iter().any(|c| c.arm == "bopf_b10"));
        for c in &b.burst {
            assert!(c.burst_rt > 0.0 && c.steady_rt > 0.0, "{}", c.arm);
        }
    }

    #[test]
    fn grids_are_deterministic() {
        let mut base = Config::default();
        base.cores = 8;
        let a = run_drf(&base, true, &Sweep::seq());
        let b = run_drf(&base, true, &Sweep::new(4));
        for (x, y) in a.mix.iter().zip(&b.mix) {
            assert_eq!(x.label, y.label);
            assert_eq!(x.mean_rt.to_bits(), y.mean_rt.to_bits());
            assert_eq!(x.cpu_core_s.to_bits(), y.cpu_core_s.to_bits());
            assert_eq!(x.mem_core_s.to_bits(), y.mem_core_s.to_bits());
        }
        for (x, y) in a.burst.iter().zip(&b.burst) {
            assert_eq!(x.arm, y.arm);
            assert_eq!(x.burst_rt.to_bits(), y.burst_rt.to_bits());
            assert_eq!(x.steady_rt.to_bits(), y.steady_rt.to_bits());
        }
    }

    #[test]
    fn unit_vector_policies_see_mixed_demands_without_feasibility_change() {
        // Every mixed-demand vector fits a unit slot, so slot-count
        // policies complete the same workload; only the ledgers differ.
        let jobs = mixed_workload(true, 7);
        for j in &jobs {
            j.validate().unwrap();
            for s in &j.stages {
                assert!(s.demand.fits(&ResourceVec::UNIT));
            }
        }
        assert!(jobs.iter().any(|j| !j.stages[0].demand.is_unit()));
    }
}
