//! The shard harness behind `uwfq shard` and `benches/shard.rs`:
//! the scale workload run through the sharded engine
//! ([`crate::sim::run_sharded`]) at increasing shard counts, with the
//! 1-shard run as its own throughput baseline.
//!
//! Each row is one full run: users hash-partitioned across `S`
//! independent event loops (each owning `cores/S` cores), federated
//! virtual time re-coupled at the sync barrier every `shard_epoch_s` of
//! simulated time. The row records wall-clock throughput
//! (`jobs_per_s`, `speedup_vs_1shard`), the merged simulation outcome
//! (exact counter sums; ECDF-derived quantiles), and the virtual-time
//! drift telemetry (`max_drift_rsec` against the provable
//! `bound_rsec = cores × shard_epoch_s`).

use std::collections::HashMap;
use std::time::Instant;

use crate::config::Config;
use crate::metrics::streaming::StreamingRunMetrics;
use crate::sim::{run_sharded, SimOpts, SyncStats};
use crate::util::benchkit::JsonSink;
use crate::workload::stream::{scale_stream, ScaleParams};
use crate::workload::stress::{skewed, SkewedParams};

use super::scale::{scale_idle_map, QUANTILES};

/// One shard count's full run.
#[derive(Clone, Debug)]
pub struct ShardRow {
    pub shards: u32,
    pub wall_s: f64,
    pub jobs_per_s: f64,
    /// Throughput relative to this outcome's own 1-shard row.
    pub speedup_vs_1shard: f64,
    pub jobs: u64,
    pub task_events: u64,
    /// Sum of per-shard peak-in-flight counters (upper bound on the
    /// cluster peak; comparable to the unsharded key at S=1).
    pub peak_in_flight_sum: usize,
    /// Max of per-shard peaks — the largest single event loop.
    pub peak_in_flight_max: usize,
    pub makespan_s: f64,
    pub utilization: f64,
    /// Sync-barrier telemetry (0 epochs at S=1).
    pub epochs: u64,
    pub max_drift_rsec: f64,
    pub bound_rsec: f64,
    pub mean_rt: f64,
    pub mean_slowdown: f64,
    pub jain_index: f64,
    pub user_count: usize,
    /// ECDF-inverted RT quantiles of the merged sink (exactly mergeable,
    /// unlike P²).
    pub ecdf_q: [f64; 3],
}

/// Everything one `uwfq shard` invocation produces.
pub struct ShardOutcome {
    pub label: String,
    pub jobs: u64,
    pub users: u32,
    pub cores: u32,
    pub rows: Vec<ShardRow>,
}

/// Run the scale workload at each shard count in `shard_counts`
/// (deduplicated, ascending; a 1-shard run is prepended if absent so the
/// speedup baseline is always measured in-process).
pub fn run_shard(params: &ScaleParams, cfg: &Config, shard_counts: &[u32]) -> ShardOutcome {
    let mut counts: Vec<u32> = shard_counts.to_vec();
    if !counts.contains(&1) {
        counts.push(1);
    }
    counts.sort_unstable();
    counts.dedup();

    let idle = scale_idle_map(cfg);
    let label = cfg.label();
    let mut rows = Vec::with_capacity(counts.len());
    for &s in &counts {
        let mut cfg_s = cfg.clone();
        cfg_s.shards = s;
        let t0 = Instant::now();
        let run = run_sharded(
            &cfg_s,
            SimOpts::default(),
            |_| scale_stream(params),
            |_| StreamingRunMetrics::new(&label, idle.clone()),
        );
        let wall_s = t0.elapsed().as_secs_f64().max(1e-9);

        // Fold the shard-local sinks into one (exact reduction; users are
        // disjoint across shards so per-user aggregates never collide).
        let mut sinks = run.sinks.into_iter();
        let mut merged = sinks.next().expect("at least one shard");
        for sink in sinks {
            merged.merge_from(&sink);
        }

        let sum = &run.summary;
        rows.push(ShardRow {
            shards: s,
            wall_s,
            jobs_per_s: sum.jobs_completed as f64 / wall_s,
            speedup_vs_1shard: 0.0, // filled below, once the baseline exists
            jobs: sum.jobs_completed,
            task_events: sum.task_events,
            peak_in_flight_sum: sum.peak_in_flight_jobs,
            peak_in_flight_max: run.peak_in_flight_max,
            makespan_s: sum.makespan_s,
            utilization: sum.utilization,
            epochs: run.sync.epochs,
            max_drift_rsec: run.sync.max_drift_rsec,
            bound_rsec: run.sync.bound_rsec,
            mean_rt: merged.mean_rt(),
            mean_slowdown: merged.mean_slowdown(),
            jain_index: merged.jain_index_user_rt(),
            user_count: merged.user_count(),
            ecdf_q: QUANTILES.map(|p| merged.rt_quantile_ecdf(p)),
        });
    }

    let base = rows
        .iter()
        .find(|r| r.shards == 1)
        .map(|r| r.jobs_per_s)
        .expect("1-shard baseline is always present");
    for r in &mut rows {
        r.speedup_vs_1shard = if base > 0.0 { r.jobs_per_s / base } else { 0.0 };
    }

    ShardOutcome {
        label,
        jobs: params.jobs,
        users: params.users,
        cores: cfg.cores,
        rows,
    }
}

// ---------------------------------------------------------------------------
// Skew ablation (`uwfq shard --skew`)
// ---------------------------------------------------------------------------

/// One shard count's skew-ablation row: the Zipfian `skewed` stream run
/// with the static core split (`rebalance=off`) and, unless lending is
/// disabled, again with deterministic cross-shard core lending on.
#[derive(Clone, Debug)]
pub struct SkewRow {
    pub shards: u32,
    /// Lending-arm wall clock / throughput (equals the static arm when
    /// lending is disabled or `S == 1`, where lending is a no-op).
    pub wall_s: f64,
    pub jobs_per_s: f64,
    pub static_jobs_per_s: f64,
    /// `jobs_per_s / static_jobs_per_s`; 1.0 when only the static arm ran.
    pub speedup_vs_static: f64,
    pub jobs: u64,
    pub epochs: u64,
    pub lend_events: u64,
    pub max_backlog_imbalance: f64,
    pub max_drift_rsec: f64,
    pub bound_rsec: f64,
}

/// Everything one `uwfq shard --skew` invocation produces.
pub struct SkewOutcome {
    pub label: String,
    pub params: SkewedParams,
    pub cores: u32,
    /// Whether the lending arm ran (false = static-only ablation).
    pub lending: bool,
    pub rows: Vec<SkewRow>,
}

/// One sharded run of the `skewed` stream; returns (wall_s, jobs, sync).
fn skew_run(seed: u64, p: &SkewedParams, cfg: &Config) -> (f64, u64, SyncStats) {
    let label = cfg.label();
    let t0 = Instant::now();
    let run = run_sharded(
        cfg,
        SimOpts::default(),
        |_| skewed(seed, p).expect("skewed params validated by the harness"),
        // Skewed job names are unique per job, so a template idle map
        // does not apply; slowdown columns are not recorded here.
        |_| StreamingRunMetrics::new(&label, HashMap::new()),
    );
    let wall_s = t0.elapsed().as_secs_f64().max(1e-9);
    (wall_s, run.summary.jobs_completed, run.sync)
}

/// Run the skew ablation at each shard count: every count gets a
/// `rebalance=off` (static split) arm; counts > 1 additionally get a
/// lending-on arm when `lending` is set, and `speedup_vs_static` is the
/// on/off throughput ratio on identical work.
pub fn run_shard_skew(
    seed: u64,
    params: &SkewedParams,
    cfg: &Config,
    shard_counts: &[u32],
    lending: bool,
) -> SkewOutcome {
    let mut counts: Vec<u32> = shard_counts.to_vec();
    counts.sort_unstable();
    counts.dedup();

    let mut rows = Vec::with_capacity(counts.len());
    for &s in &counts {
        let mut cfg_off = cfg.clone();
        cfg_off.shards = s;
        cfg_off.shard_rebalance = false;
        let (off_wall, off_jobs, off_sync) = skew_run(seed, params, &cfg_off);
        let static_jobs_per_s = off_jobs as f64 / off_wall;

        let (wall_s, jobs, sync) = if lending && s > 1 {
            let mut cfg_on = cfg_off.clone();
            cfg_on.shard_rebalance = true;
            skew_run(seed, params, &cfg_on)
        } else {
            (off_wall, off_jobs, off_sync)
        };
        let jobs_per_s = jobs as f64 / wall_s;
        rows.push(SkewRow {
            shards: s,
            wall_s,
            jobs_per_s,
            static_jobs_per_s,
            speedup_vs_static: if static_jobs_per_s > 0.0 {
                jobs_per_s / static_jobs_per_s
            } else {
                0.0
            },
            jobs,
            epochs: sync.epochs,
            lend_events: sync.lend_events,
            max_backlog_imbalance: sync.max_backlog_imbalance,
            max_drift_rsec: sync.max_drift_rsec,
            bound_rsec: sync.bound_rsec,
        });
    }

    SkewOutcome {
        label: cfg.label(),
        params: params.clone(),
        cores: cfg.cores,
        lending,
        rows,
    }
}

/// Record a skew outcome into a benchkit sink (`shard/skew/...` keys in
/// `BENCH_shard.json` / `BENCH_shard-skew-{on,off}.json`).
pub fn record_skew_metrics(o: &SkewOutcome, sink: &mut JsonSink) {
    sink.metric("shard/skew/jobs", o.params.jobs as f64);
    sink.metric("shard/skew/users", o.params.users as f64);
    sink.metric("shard/skew/cores", o.cores as f64);
    sink.metric("shard/skew/zipf_s", o.params.zipf_s);
    sink.metric("shard/skew/hot_users", o.params.hot_users as f64);
    sink.metric("shard/skew/lending", if o.lending { 1.0 } else { 0.0 });
    for r in &o.rows {
        let s = r.shards;
        sink.metric(&format!("shard/skew/s{s}/wall_s"), r.wall_s);
        sink.metric(&format!("shard/skew/s{s}/jobs"), r.jobs as f64);
        sink.metric(&format!("shard/skew/s{s}/jobs_per_s"), r.jobs_per_s);
        sink.metric(
            &format!("shard/skew/s{s}/static_jobs_per_s"),
            r.static_jobs_per_s,
        );
        sink.metric(
            &format!("shard/skew/s{s}/speedup_vs_static"),
            r.speedup_vs_static,
        );
        sink.metric(&format!("shard/skew/s{s}/sync_epochs"), r.epochs as f64);
        sink.metric(&format!("shard/skew/s{s}/lend_events"), r.lend_events as f64);
        sink.metric(
            &format!("shard/skew/s{s}/max_backlog_imbalance"),
            r.max_backlog_imbalance,
        );
        sink.metric(&format!("shard/skew/s{s}/max_drift_rsec"), r.max_drift_rsec);
        sink.metric(&format!("shard/skew/s{s}/drift_bound_rsec"), r.bound_rsec);
    }
}

/// Human summary printed by `uwfq shard --skew`.
pub fn render_skew(o: &SkewOutcome) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "shard skew bench ({}): {} jobs / {} users ({} hot, zipf_s {}) on {} cores, lending {}\n",
        o.label,
        o.params.jobs,
        o.params.users,
        o.params.hot_users,
        o.params.zipf_s,
        o.cores,
        if o.lending { "on" } else { "off" },
    ));
    s.push_str(
        "  shards     jobs/s   static j/s  speedup    lends  imbalance   drift rsec (bound)\n",
    );
    for r in &o.rows {
        s.push_str(&format!(
            "  {:>6} {:>10.0} {:>12.0} {:>8.2}x {:>8} {:>10.2} {:>12.3} ({:>6.1})\n",
            r.shards,
            r.jobs_per_s,
            r.static_jobs_per_s,
            r.speedup_vs_static,
            r.lend_events,
            r.max_backlog_imbalance,
            r.max_drift_rsec,
            r.bound_rsec
        ));
    }
    s
}

/// Record a shard outcome into a benchkit sink (`BENCH_shard.json`,
/// tracked across PRs next to `BENCH_scale` / `BENCH_hotpath`).
pub fn record_metrics(o: &ShardOutcome, sink: &mut JsonSink) {
    sink.metric("shard/jobs", o.jobs as f64);
    sink.metric("shard/users", o.users as f64);
    sink.metric("shard/cores", o.cores as f64);
    for r in &o.rows {
        let s = r.shards;
        sink.metric(&format!("shard/s{s}/wall_s"), r.wall_s);
        sink.metric(&format!("shard/s{s}/jobs_per_s"), r.jobs_per_s);
        sink.metric(&format!("shard/s{s}/speedup_vs_1shard"), r.speedup_vs_1shard);
        sink.metric(&format!("shard/s{s}/task_events"), r.task_events as f64);
        sink.metric(
            &format!("shard/s{s}/peak_in_flight_sum"),
            r.peak_in_flight_sum as f64,
        );
        sink.metric(
            &format!("shard/s{s}/peak_in_flight_max"),
            r.peak_in_flight_max as f64,
        );
        sink.metric(&format!("shard/s{s}/makespan_s"), r.makespan_s);
        sink.metric(&format!("shard/s{s}/utilization"), r.utilization);
        sink.metric(&format!("shard/s{s}/sync_epochs"), r.epochs as f64);
        sink.metric(&format!("shard/s{s}/max_drift_rsec"), r.max_drift_rsec);
        sink.metric(&format!("shard/s{s}/drift_bound_rsec"), r.bound_rsec);
        sink.metric(&format!("shard/s{s}/mean_rt_s"), r.mean_rt);
        sink.metric(&format!("shard/s{s}/jain_index_user_rt"), r.jain_index);
        for (i, p) in QUANTILES.iter().enumerate() {
            let tag = (p * 100.0).round() as u32;
            sink.metric(&format!("shard/s{s}/rt_p{tag}_ecdf_s"), r.ecdf_q[i]);
        }
    }
}

/// Human summary printed by `uwfq shard` and the bench.
pub fn render(o: &ShardOutcome) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "shard bench ({}): {} jobs / {} users on {} cores\n",
        o.label, o.jobs, o.users, o.cores
    ));
    s.push_str(
        "  shards     jobs/s  speedup   wall s   drift rsec (bound)   epochs  Jain\n",
    );
    for r in &o.rows {
        s.push_str(&format!(
            "  {:>6} {:>10.0} {:>8.2}x {:>8.2}   {:>10.3} ({:>6.1}) {:>8} {:>5.3}\n",
            r.shards,
            r.jobs_per_s,
            r.speedup_vs_1shard,
            r.wall_s,
            r.max_drift_rsec,
            r.bound_rsec,
            r.epochs,
            r.jain_index
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_params() -> ScaleParams {
        ScaleParams {
            users: 40,
            jobs: 500,
            cores: 8,
            target_utilization: 0.8,
            seed: 11,
        }
    }

    #[test]
    fn run_shard_always_has_a_baseline_and_consistent_rows() {
        let cfg = Config::default().with_cores(8);
        // 1 deliberately omitted: run_shard must prepend the baseline.
        let o = run_shard(&small_params(), &cfg, &[2]);
        assert_eq!(o.rows.len(), 2);
        assert_eq!(o.rows[0].shards, 1);
        assert_eq!(o.rows[1].shards, 2);
        assert!((o.rows[0].speedup_vs_1shard - 1.0).abs() < 1e-12);
        for r in &o.rows {
            assert_eq!(r.jobs, 500, "S={} dropped jobs", r.shards);
            assert_eq!(r.user_count, 40);
            assert!(r.jobs_per_s > 0.0);
            assert!(r.peak_in_flight_max <= r.peak_in_flight_sum);
            assert!(
                r.max_drift_rsec <= r.bound_rsec + 1e-9,
                "S={}: drift {} over bound {}",
                r.shards,
                r.max_drift_rsec,
                r.bound_rsec
            );
        }
        assert_eq!(o.rows[0].epochs, 0, "S=1 must not sync");
        assert!(o.rows[1].epochs > 0, "S=2 must sync");
    }

    fn small_skew_params() -> SkewedParams {
        SkewedParams {
            users: 40,
            jobs: 600,
            zipf_s: 1.2,
            hot_users: 8,
            cores: 8,
            target_utilization: 0.7,
            skew_fraction: 0.2,
        }
    }

    #[test]
    fn run_shard_skew_ablates_lending_per_shard_count() {
        let cfg = Config::default().with_cores(8);
        let o = run_shard_skew(11, &small_skew_params(), &cfg, &[2, 1], true);
        assert_eq!(o.rows.len(), 2);
        assert_eq!(o.rows[0].shards, 1);
        assert_eq!(o.rows[1].shards, 2);
        for r in &o.rows {
            assert_eq!(r.jobs, 600, "S={} dropped jobs", r.shards);
            assert!(r.jobs_per_s > 0.0 && r.static_jobs_per_s > 0.0);
            assert!(r.speedup_vs_static > 0.0);
            assert!(
                r.max_drift_rsec <= r.bound_rsec + 1e-9,
                "S={}: drift {} over bound {}",
                r.shards,
                r.max_drift_rsec,
                r.bound_rsec
            );
        }
        // S=1 never lends (lending is a no-op, only the static arm runs).
        assert_eq!(o.rows[0].lend_events, 0);
        assert!((o.rows[0].speedup_vs_static - 1.0).abs() < 1e-12);
        // With lending disabled every row is its own static arm.
        let off = run_shard_skew(11, &small_skew_params(), &cfg, &[2], false);
        assert!(!off.lending);
        assert_eq!(off.rows[0].lend_events, 0);
        assert!((off.rows[0].speedup_vs_static - 1.0).abs() < 1e-12);
    }

    #[test]
    fn record_skew_metrics_emits_ablation_keys() {
        let cfg = Config::default().with_cores(8);
        let o = run_shard_skew(3, &small_skew_params(), &cfg, &[2], true);
        let mut sink = JsonSink::new();
        record_skew_metrics(&o, &mut sink);
        let path = std::env::temp_dir().join("uwfq_shard_skew_metrics_test.json");
        sink.write(path.to_str().unwrap()).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        for key in [
            "shard/skew/s2/jobs_per_s",
            "shard/skew/s2/static_jobs_per_s",
            "shard/skew/s2/speedup_vs_static",
            "shard/skew/s2/lend_events",
            "shard/skew/s2/max_backlog_imbalance",
            "shard/skew/s2/max_drift_rsec",
            "shard/skew/zipf_s",
        ] {
            assert!(text.contains(key), "missing {key}");
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn record_metrics_emits_per_shard_keys() {
        let cfg = Config::default().with_cores(8);
        let o = run_shard(&small_params(), &cfg, &[1, 2]);
        let mut sink = JsonSink::new();
        record_metrics(&o, &mut sink);
        let path = std::env::temp_dir().join("uwfq_shard_metrics_test.json");
        sink.write(path.to_str().unwrap()).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        for key in [
            "shard/s1/jobs_per_s",
            "shard/s2/speedup_vs_1shard",
            "shard/s2/max_drift_rsec",
            "shard/s2/peak_in_flight_max",
        ] {
            assert!(text.contains(key), "missing {key}");
        }
        std::fs::remove_file(path).ok();
    }
}
