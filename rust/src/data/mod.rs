//! Synthetic trip-record dataset — the stand-in for the paper's NYC TLC
//! FHVHV Parquet file (§5.2: 752 MB, 19.1 M rows, partitioned on
//! `PULocationID` into row groups).
//!
//! We generate deterministic pseudo-random f32 row blocks with the same
//! columnar geometry the AOT artifacts expect (4096 rows × 8 columns).
//! Column semantics mirror the TLC schema loosely (location id, trip
//! miles/minutes, fares, tips ...) so the analytics computation operates
//! on realistically distributed values; what matters to the scheduler is
//! bytes, rows and row-group layout.

pub mod table;

pub use table::{TripTable, BLOCK_COLS, BLOCK_ROWS};
