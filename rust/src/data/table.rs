//! Deterministic columnar trip-record blocks.

use crate::core::engine::BLOCK_BYTES;
use crate::util::Rng;

/// Rows per block — must match `python/compile/kernels/rowops.py::ROWS`
/// and the AOT manifest.
pub const BLOCK_ROWS: usize = 4096;
/// Columns per block — must match `rowops.py::COLS`.
pub const BLOCK_COLS: usize = 8;

/// A synthetic trip-record table of `blocks` row groups.
///
/// Blocks are generated lazily and deterministically from (seed, block
/// index), so a table is just a descriptor — no resident memory until a
/// task materializes its partition.
#[derive(Clone, Debug)]
pub struct TripTable {
    pub seed: u64,
    pub blocks: u64,
}

impl TripTable {
    pub fn new(seed: u64, blocks: u64) -> Self {
        assert!(blocks > 0);
        TripTable { seed, blocks }
    }

    /// A table sized like the paper's dataset (752 MB of f32 blocks).
    pub fn paper_sized(seed: u64) -> Self {
        TripTable::new(seed, (752 << 20) / BLOCK_BYTES)
    }

    pub fn bytes(&self) -> u64 {
        self.blocks * BLOCK_BYTES
    }

    pub fn rows(&self) -> u64 {
        self.blocks * BLOCK_ROWS as u64
    }

    /// Materialize block `i` in row-major order (rows × cols), suitable
    /// for `Literal::vec1(..).reshape([ROWS, COLS])`.
    ///
    /// Columns (loosely mirroring the TLC FHVHV schema):
    /// 0 `PULocationID`-ish categorical (1..=263), 1 trip miles,
    /// 2 trip minutes, 3 base fare, 4 tolls, 5 tips, 6 congestion
    /// surcharge, 7 driver pay. Values are heavy-tailed where the real
    /// columns are.
    pub fn block(&self, i: u64) -> Vec<f32> {
        assert!(i < self.blocks, "block {i} out of range {}", self.blocks);
        let mut rng = Rng::new(self.seed ^ (i.wrapping_mul(0x9E3779B97F4A7C15)));
        let mut out = Vec::with_capacity(BLOCK_ROWS * BLOCK_COLS);
        for _ in 0..BLOCK_ROWS {
            let loc = 1.0 + rng.below(263) as f32;
            let miles = rng.lognormal(0.9, 0.8) as f32;
            let minutes = (miles * 3.2 + rng.lognormal(1.2, 0.5) as f32).max(1.0);
            let fare = 2.5 + 1.9 * miles + 0.5 * minutes + rng.normal() as f32 * 0.8;
            let tolls = if rng.f64() < 0.08 {
                rng.lognormal(1.8, 0.3) as f32
            } else {
                0.0
            };
            let tips = if rng.f64() < 0.25 {
                (fare * rng.range_f64(0.1, 0.3) as f32).max(0.0)
            } else {
                0.0
            };
            let congestion = if loc < 90.0 { 2.75 } else { 0.0 };
            let pay = (fare * 0.72 + tolls).max(0.0);
            out.extend_from_slice(&[loc, miles, minutes, fare.max(2.5), tolls, tips, congestion, pay]);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geometry_matches_artifacts() {
        assert_eq!(BLOCK_ROWS * BLOCK_COLS * 4, BLOCK_BYTES as usize);
    }

    #[test]
    fn deterministic_blocks() {
        let t = TripTable::new(7, 4);
        assert_eq!(t.block(2), t.block(2));
        assert_ne!(t.block(0), t.block(1));
    }

    #[test]
    fn block_shape_and_sanity() {
        let t = TripTable::new(1, 2);
        let b = t.block(0);
        assert_eq!(b.len(), BLOCK_ROWS * BLOCK_COLS);
        for row in b.chunks(BLOCK_COLS) {
            assert!((1.0..=263.0).contains(&row[0])); // location id
            assert!(row[1] > 0.0); // miles
            assert!(row[3] >= 2.5); // fare floor
            assert!(row.iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn paper_sized_table() {
        let t = TripTable::paper_sized(42);
        assert_eq!(t.bytes(), 752 << 20);
        assert_eq!(t.rows(), t.blocks * 4096);
    }

    #[test]
    #[should_panic]
    fn out_of_range_block_panics() {
        TripTable::new(1, 1).block(1);
    }
}
