//! Real execution backend: the same [`SchedCore`] scheduling loop as the
//! simulator, but tasks *actually execute* the AOT-compiled analytics
//! kernel on synthetic trip-record blocks via PJRT.
//!
//! Topology (paper Fig. 1, scaled down): the driver thread owns the
//! scheduler state and the wall clock; each executor core is a worker
//! thread owning its own [`ArtifactStore`] (PJRT clients are not `Sync`).
//! Workers receive task assignments over a channel and report completions
//! (with computed partials) back; the driver folds compute partials into
//! per-job state and hands them to the collect stage's `aggregate`
//! artifact — so every analytics job produces real numerics end to end.

use std::collections::HashMap;
use std::path::Path;
use std::sync::mpsc;
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::core::dag::CompletedJob;
use crate::core::job::JobSpec;
use crate::core::{Launch, SchedCore};
use crate::config::Config;
use crate::data::TripTable;
use crate::runtime::ArtifactStore;
use crate::{JobId, TimeUs};

/// Work sent to an executor core.
enum ToWorker {
    Run(RealTask),
    Shutdown,
}

struct RealTask {
    kind: TaskKind,
}

enum TaskKind {
    /// Run the k-op compute artifact over `blocks` consecutive blocks of
    /// the job's table.
    Compute {
        table_seed: u64,
        block_start: u64,
        blocks: u32,
        table_blocks: u64,
        k: u32,
    },
    /// Fold per-task partials into final [mean; var] via the aggregate
    /// artifact.
    Aggregate { partials: Vec<(Vec<f32>, f32)> },
}

struct FromWorker {
    core: usize,
    /// Partial [sum; sumsq] (+rows) from compute tasks.
    partial: Option<(Vec<f32>, f32)>,
    /// Final [mean; var] from aggregate tasks.
    final_out: Option<Vec<f32>>,
    err: Option<String>,
}

/// Outcome of a real-backend run.
pub struct RealReport {
    pub completed: Vec<CompletedJob>,
    /// Final [mean; var] analytics output per job.
    pub results: HashMap<JobId, Vec<f32>>,
    pub makespan_s: f64,
    /// Mean task wall time (seconds) per op-count variant, for
    /// calibration against the simulator.
    pub task_wall: HashMap<u32, (f64, usize)>,
}

/// Run a workload on the real backend. `cfg.cores` worker threads are
/// spawned, each compiling the artifacts once at startup.
///
/// `time_scale` compresses the workload timeline (e.g. 0.1 = 10× faster
/// arrivals) so examples finish quickly while preserving arrival order.
pub fn run_real(
    cfg: Config,
    mut jobs: Vec<JobSpec>,
    artifact_dir: &Path,
    time_scale: f64,
) -> Result<RealReport> {
    anyhow::ensure!(time_scale > 0.0);
    jobs.sort_by_key(|j| j.arrival);
    for j in &mut jobs {
        j.arrival = (j.arrival as f64 * time_scale) as TimeUs;
    }

    let cores = cfg.cores as usize;
    let (done_tx, done_rx) = mpsc::channel::<FromWorker>();
    let mut workers = Vec::new();
    let mut senders: Vec<mpsc::Sender<ToWorker>> = Vec::new();
    for core_idx in 0..cores {
        let (tx, rx) = mpsc::channel::<ToWorker>();
        senders.push(tx);
        let done = done_tx.clone();
        let dir = artifact_dir.to_path_buf();
        workers.push(
            thread::Builder::new()
                .name(format!("executor-{core_idx}"))
                .spawn(move || worker_loop(core_idx, &dir, rx, done))
                .context("spawning executor thread")?,
        );
    }
    drop(done_tx);

    let mut core = SchedCore::from_config(cfg);
    let mut results: HashMap<JobId, Vec<f32>> = HashMap::new();
    let mut partials: HashMap<JobId, Vec<(Vec<f32>, f32)>> = HashMap::new();
    let mut task_wall_acc: HashMap<u32, (f64, usize)> = HashMap::new();
    let mut task_started: HashMap<usize, (Instant, u32)> = HashMap::new();

    let t0 = Instant::now();
    let now_us = |t0: &Instant| t0.elapsed().as_micros() as TimeUs;
    let mut next_arrival = 0usize;
    let total_jobs = jobs.len();
    let mut launch_buf: Vec<Launch> = Vec::new();

    while core.completed.len() < total_jobs {
        let now = now_us(&t0);
        // Submit due arrivals.
        while next_arrival < jobs.len() && jobs[next_arrival].arrival <= now {
            core.submit_job(now, jobs[next_arrival].clone())?;
            next_arrival += 1;
        }
        // Launch onto free cores (reusable buffer, no per-poll allocation).
        core.try_launch_into(now, &mut launch_buf);
        for launch in &launch_buf {
            let task = build_task(&core, launch, &mut partials);
            task_started.insert(launch.core, (Instant::now(), launch.opcount));
            senders[launch.core]
                .send(ToWorker::Run(task))
                .map_err(|_| anyhow::anyhow!("executor {} died", launch.core))?;
        }
        // Wait for a completion or the next arrival.
        let timeout = if next_arrival < jobs.len() {
            Duration::from_micros(jobs[next_arrival].arrival.saturating_sub(now_us(&t0)).max(200))
        } else {
            Duration::from_millis(50)
        };
        match done_rx.recv_timeout(timeout) {
            Ok(msg) => {
                if let Some(e) = msg.err {
                    anyhow::bail!("task failed on core {}: {e}", msg.core);
                }
                let now = now_us(&t0);
                let job = core
                    .core_state(msg.core)
                    .expect("completion from idle core")
                    .job;
                if let Some((t_start, k)) = task_started.remove(&msg.core) {
                    let e = task_wall_acc.entry(k).or_insert((0.0, 0));
                    e.0 += t_start.elapsed().as_secs_f64();
                    e.1 += 1;
                }
                if let Some(p) = msg.partial {
                    partials.entry(job).or_default().push(p);
                }
                if let Some(f) = msg.final_out {
                    results.insert(job, f);
                }
                core.task_finished(now, msg.core);
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                anyhow::bail!("all executors disconnected")
            }
        }
    }

    for tx in &senders {
        let _ = tx.send(ToWorker::Shutdown);
    }
    for w in workers {
        let _ = w.join();
    }

    let makespan_s = crate::us_to_s(core.completed.iter().map(|c| c.finish).max().unwrap_or(0));
    let task_wall = task_wall_acc
        .into_iter()
        .map(|(k, (sum, n))| (k, (sum / n.max(1) as f64, n)))
        .collect();
    Ok(RealReport {
        completed: core.completed,
        results,
        makespan_s,
        task_wall,
    })
}

/// Map an engine launch onto a real task description.
fn build_task(
    core: &SchedCore,
    launch: &Launch,
    partials: &mut HashMap<JobId, Vec<(Vec<f32>, f32)>>,
) -> RealTask {
    let stage = core.stage(launch.stage).expect("launched stage exists");
    // A single-task non-leaf stage is the job's collect stage (declared
    // with max_parallelism = 1): it folds the compute partials.
    let is_collect = stage.tasks.len() == 1 && stage.idx > 0;
    if is_collect {
        let ps = partials.remove(&launch.job).unwrap_or_default();
        if !ps.is_empty() {
            return RealTask {
                kind: TaskKind::Aggregate { partials: ps },
            };
        }
        // No partials yet (unusual DAG shape): fall through to compute.
    }
    let table_blocks = 64u64; // per-job logical table (64 blocks = 8 MB)
    let block_start = (launch.task_idx as u64 * launch.blocks as u64) % table_blocks;
    RealTask {
        kind: TaskKind::Compute {
            table_seed: launch.job,
            block_start,
            blocks: launch.blocks.min(table_blocks as u32),
            table_blocks,
            k: launch.opcount,
        },
    }
}

fn worker_loop(
    core: usize,
    dir: &Path,
    rx: mpsc::Receiver<ToWorker>,
    done: mpsc::Sender<FromWorker>,
) {
    let store = match ArtifactStore::load(dir) {
        Ok(s) => s,
        Err(e) => {
            let _ = done.send(FromWorker {
                core,
                partial: None,
                final_out: None,
                err: Some(format!("artifact load: {e:#}")),
            });
            return;
        }
    };
    while let Ok(msg) = rx.recv() {
        let task = match msg {
            ToWorker::Run(t) => t,
            ToWorker::Shutdown => break,
        };
        let out = execute(&store, &task.kind);
        let msg = match out {
            Ok((partial, final_out)) => FromWorker {
                core,
                partial,
                final_out,
                err: None,
            },
            Err(e) => FromWorker {
                core,
                partial: None,
                final_out: None,
                err: Some(format!("{e:#}")),
            },
        };
        if done.send(msg).is_err() {
            break;
        }
    }
}

type TaskOutput = (Option<(Vec<f32>, f32)>, Option<Vec<f32>>);

fn execute(store: &ArtifactStore, kind: &TaskKind) -> Result<TaskOutput> {
    match kind {
        TaskKind::Compute {
            table_seed,
            block_start,
            blocks,
            table_blocks,
            k,
        } => {
            let table = TripTable::new(*table_seed, *table_blocks);
            let cols = store.manifest.cols;
            let mut sum = vec![0f32; 2 * cols];
            let mut rows = 0f32;
            // Pick the nearest compiled variant at or below k.
            let variants = store.variants();
            let kk = variants
                .iter()
                .copied()
                .filter(|v| *v <= *k)
                .max()
                .or_else(|| variants.first().copied())
                .unwrap_or(1);
            for b in 0..*blocks as u64 {
                let idx = (block_start + b) % table_blocks;
                let block = table.block(idx);
                let partial = store.run_compute_block(kk, &block)?;
                for (i, v) in partial.iter().enumerate() {
                    sum[i] += v;
                }
                rows += store.manifest.block_rows as f32;
            }
            Ok((Some((sum, rows)), None))
        }
        TaskKind::Aggregate { partials } => {
            let out = store.run_aggregate(partials)?;
            Ok((None, Some(out)))
        }
    }
}
