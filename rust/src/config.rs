//! Engine configuration — the knobs of the testbed (§5.1) and of the
//! paper's algorithms, with the defaults used throughout the evaluation.
//!
//! Values can be overridden from CLI flags (`--cores`, `--atr`, ...) or a
//! simple `key = value` config file (see [`Config::from_file`]).

use crate::fault::FaultConfig;
use crate::partition::SchemeKind;
use crate::sched::PolicyKind;

#[derive(Clone, Debug)]
pub struct Config {
    /// Total executor cores `R` (DAS-5 setup: 8 executors × 4 cores = 32).
    pub cores: u32,
    /// Fixed per-task overhead in seconds (scheduling + launch + JVM-ish
    /// constant) — what makes over-partitioning costly (§3.2: "ATR should
    /// not be set too low").
    pub task_overhead: f64,
    /// Advisory Task Runtime for runtime partitioning, seconds (§3.2).
    pub atr: f64,
    /// Spark `maxPartitionBytes` (file scan), tuned as in §5.1.
    pub max_partition_bytes: u64,
    /// AQE advisory partition size (shuffle coalescing).
    pub advisory_partition_bytes: u64,
    /// UWFQ grace period in resource-seconds (§4.2; paper default 2).
    pub grace_rsec: f64,
    /// BoPF per-burst budget in estimated resource-seconds: how much
    /// work a user returning from idle may run at burst priority before
    /// falling back to long-term fair share.
    pub bopf_burst_rsec: f64,
    /// Scheduling policy.
    pub policy: PolicyKind,
    /// Partitioning scheme (`Runtime` = the paper's `-P` variants).
    pub scheme: SchemeKind,
    /// Workload / estimator RNG seed.
    pub seed: u64,
    /// σ of the lognormal estimator error (0 = perfect oracle, §6.4).
    pub estimator_sigma: f64,
    /// Record per-task start/finish for Gantt figures (small overhead).
    pub log_tasks: bool,
    /// Workload selection by registry name (`scenario = bursty` in a
    /// config file, `--scenario bursty` on the CLI). `None` = command
    /// default.
    pub scenario: Option<String>,
    /// Raw scenario parameter overrides (`param.k = v` lines, `--param
    /// k=v` flags), validated against the scenario's schema at build time
    /// ([`crate::workload::registry`]). Later entries win.
    pub scenario_params: Vec<(String, String)>,
    /// Fault injection & recovery knobs (`fault.*` keys). All rates
    /// default to zero — the fault-free path is byte-identical to a build
    /// without the subsystem.
    pub fault: FaultConfig,
    /// Shard count for the sharded engine ([`crate::sim::run_sharded`]):
    /// users are hash-partitioned across this many independent event
    /// loops, each owning `cores/shards` cores. `1` (the default) is the
    /// plain single-loop engine, byte-identical to builds before sharding
    /// existed. Must not exceed `cores` — every shard needs ≥1 core.
    pub shards: u32,
    /// Virtual-time sync epoch for sharded runs, in simulated seconds:
    /// the interval between global barriers that re-couple each shard's
    /// `v_global` and fair-share rate to the population-wide values. The
    /// fairness drift bound is `cores × shard_epoch_s` resource-seconds.
    pub shard_epoch_s: f64,
    /// Cross-shard core lending ([`crate::sim::rebalance_cores`]): at
    /// every sync barrier a pure-function rebalancer re-assigns the
    /// integer core budget across shards proportional to published
    /// backlog. Off (the default) keeps the static `cores/shards` split
    /// byte-identical to builds before lending existed.
    pub shard_rebalance: bool,
    /// Per-shard core floor under lending: no shard's allocation ever
    /// drops below this. Requires `rebalance_min_cores × shards ≤ cores`
    /// (checked up front by the sharded runner).
    pub rebalance_min_cores: u32,
    /// Max cores migrated across all shards per sync epoch — bounds how
    /// fast allocations move so the drift bound's rate-conservation
    /// argument stays local to one epoch.
    pub rebalance_cap: u32,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            cores: 32,
            task_overhead: 0.020,
            atr: 0.5,
            max_partition_bytes: 24 << 20,
            advisory_partition_bytes: 24 << 20,
            grace_rsec: 2.0,
            bopf_burst_rsec: 10.0,
            policy: PolicyKind::Uwfq,
            scheme: SchemeKind::Size,
            seed: 42,
            estimator_sigma: 0.0,
            log_tasks: false,
            scenario: None,
            scenario_params: Vec::new(),
            fault: FaultConfig::default(),
            shards: 1,
            shard_epoch_s: 4.0,
            shard_rebalance: false,
            rebalance_min_cores: 1,
            rebalance_cap: 2,
        }
    }
}

/// Every key [`Config::set`] accepts — listed in unknown-key errors.
const CONFIG_KEYS: &str = "cores, task_overhead, atr, max_partition_bytes, \
advisory_partition_bytes, grace_rsec, bopf_burst_rsec, seed, \
estimator_sigma, log_tasks, \
policy, scheme | partitioner, scenario, shards, shard_epoch_s, \
shard_rebalance, rebalance_min_cores, rebalance_cap, \
param.<name>, fault.<knob> \
(task_fail_prob, max_failures, retry_backoff_s, straggler_prob, \
straggler_mult, spec_mult, crash_mttf_s, crash_recover_s, seed)";

impl Config {
    pub fn with_policy(mut self, policy: PolicyKind) -> Self {
        self.policy = policy;
        self
    }
    pub fn with_scheme(mut self, scheme: SchemeKind) -> Self {
        self.scheme = scheme;
        self
    }
    pub fn with_cores(mut self, cores: u32) -> Self {
        self.cores = cores;
        self
    }
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Parse a `key = value` per line config file (comments with `#`).
    pub fn from_file(path: &str) -> Result<Config, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        let mut cfg = Config::default();
        cfg.apply_lines(&text)?;
        Ok(cfg)
    }

    pub fn apply_lines(&mut self, text: &str) -> Result<(), String> {
        for (ln, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected key = value", ln + 1))?;
            self.set(k.trim(), v.trim())
                .map_err(|e| format!("line {}: {e}", ln + 1))?;
        }
        Ok(())
    }

    /// Set one option by name (shared by config file and CLI flags).
    pub fn set(&mut self, key: &str, val: &str) -> Result<(), String> {
        fn num<T: std::str::FromStr>(key: &str, v: &str) -> Result<T, String> {
            v.parse()
                .map_err(|_| format!("{key}: bad number '{v}'"))
        }
        match key {
            "cores" => self.cores = num(key, val)?,
            "task_overhead" => self.task_overhead = num(key, val)?,
            "atr" => self.atr = num(key, val)?,
            "max_partition_bytes" => self.max_partition_bytes = num(key, val)?,
            "advisory_partition_bytes" => self.advisory_partition_bytes = num(key, val)?,
            "grace_rsec" => self.grace_rsec = num(key, val)?,
            "bopf_burst_rsec" => {
                let b: f64 = num(key, val)?;
                if !(b > 0.0 && b.is_finite()) {
                    return Err(format!(
                        "bopf_burst_rsec: must be a positive finite number (got \
                         '{val}'); the budget is estimated resource-seconds per burst"
                    ));
                }
                self.bopf_burst_rsec = b;
            }
            "seed" => self.seed = num(key, val)?,
            "estimator_sigma" => self.estimator_sigma = num(key, val)?,
            "log_tasks" => self.log_tasks = val == "true" || val == "1",
            "policy" => {
                self.policy = PolicyKind::parse(val).ok_or_else(|| {
                    format!(
                        "unknown policy '{val}' (valid: fifo, fair, ujf, cfq, uwfq, \
                         drf, bopf)"
                    )
                })?
            }
            "scheme" | "partitioner" => self.scheme = SchemeKind::parse(val)?,
            "scenario" => self.scenario = Some(val.to_string()),
            "shards" => {
                let s: u32 = num(key, val)?;
                if s == 0 {
                    return Err("shards: must be >= 1 (note: shards multiplies with \
                                --threads — the harness caps threads x shards at \
                                available parallelism)"
                        .into());
                }
                self.shards = s;
            }
            "shard_epoch_s" => {
                let e: f64 = num(key, val)?;
                if !(e > 0.0) {
                    return Err(format!(
                        "shard_epoch_s: must be > 0 (got '{val}'); the drift bound \
                         is cores x shard_epoch_s resource-seconds"
                    ));
                }
                self.shard_epoch_s = e;
            }
            "shard_rebalance" => match val {
                "true" | "1" => self.shard_rebalance = true,
                "false" | "0" => self.shard_rebalance = false,
                _ => {
                    return Err(format!(
                        "shard_rebalance: expected true/false (got '{val}')"
                    ))
                }
            },
            "rebalance_min_cores" => {
                let m: u32 = num(key, val)?;
                if m == 0 {
                    return Err("rebalance_min_cores: must be >= 1 (every shard \
                                keeps at least one core under lending)"
                        .into());
                }
                self.rebalance_min_cores = m;
            }
            "rebalance_cap" => {
                let c: u32 = num(key, val)?;
                if c == 0 {
                    return Err("rebalance_cap: must be >= 1 (cores migrated per \
                                epoch; set shard_rebalance = false to disable \
                                lending instead)"
                        .into());
                }
                self.rebalance_cap = c;
            }
            _ => {
                if let Some(knob) = key.strip_prefix("fault.") {
                    match knob {
                        "task_fail_prob" => self.fault.task_fail_prob = num(key, val)?,
                        "max_failures" => self.fault.max_failures = num(key, val)?,
                        "retry_backoff_s" => self.fault.retry_backoff_s = num(key, val)?,
                        "straggler_prob" => self.fault.straggler_prob = num(key, val)?,
                        "straggler_mult" => self.fault.straggler_mult = num(key, val)?,
                        "spec_mult" => self.fault.spec_mult = num(key, val)?,
                        "crash_mttf_s" => self.fault.crash_mttf_s = num(key, val)?,
                        "crash_recover_s" => self.fault.crash_recover_s = num(key, val)?,
                        "seed" => self.fault.seed = num(key, val)?,
                        _ => {
                            return Err(format!(
                                "unknown fault knob '{key}' (valid keys: {CONFIG_KEYS})"
                            ))
                        }
                    }
                    self.fault.validate()?;
                } else if let Some(param) = key.strip_prefix("param.") {
                    if param.is_empty() {
                        return Err("empty param name (use param.<name> = value)".into());
                    }
                    self.scenario_params.push((param.to_string(), val.to_string()));
                } else {
                    return Err(format!(
                        "unknown config key '{key}' (valid keys: {CONFIG_KEYS})"
                    ));
                }
            }
        }
        Ok(())
    }

    /// A short label like "UWFQ-P" matching the paper's table rows.
    pub fn label(&self) -> String {
        match self.scheme {
            SchemeKind::Size => self.policy.name().to_string(),
            SchemeKind::Runtime => format!("{}-P", self.policy.name()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper_testbed() {
        let c = Config::default();
        assert_eq!(c.cores, 32);
        assert_eq!(c.grace_rsec, 2.0);
        assert_eq!(c.estimator_sigma, 0.0); // perfect predictor assumption
    }

    #[test]
    fn apply_lines_parses() {
        let mut c = Config::default();
        c.apply_lines("cores = 8\npolicy = cfq\nscheme = runtime # -P\natr=0.25\n")
            .unwrap();
        assert_eq!(c.cores, 8);
        assert_eq!(c.policy, PolicyKind::Cfq);
        assert_eq!(c.scheme, SchemeKind::Runtime);
        assert_eq!(c.atr, 0.25);
    }

    #[test]
    fn apply_lines_rejects_unknown_listing_valid_keys() {
        let mut c = Config::default();
        let err = c.apply_lines("bogus = 1").unwrap_err();
        assert!(err.contains("unknown config key 'bogus'"), "{err}");
        assert!(err.contains("scenario") && err.contains("atr"), "{err}");
        let err = c.apply_lines("policy = zzz").unwrap_err();
        assert!(err.contains("uwfq"), "{err}");
        let err = c.apply_lines("scheme = zzz").unwrap_err();
        assert!(err.contains("runtime"), "{err}");
        assert!(c.apply_lines("no equals sign").is_err());
        assert!(c.apply_lines("param. = 1").is_err());
    }

    #[test]
    fn scheme_accepts_paper_spelling() {
        let mut c = Config::default();
        c.apply_lines("scheme = -P").unwrap();
        assert_eq!(c.scheme, SchemeKind::Runtime);
    }

    #[test]
    fn scenario_and_params_parse() {
        let mut c = Config::default();
        c.apply_lines("scenario = bursty\nparam.burst_ratio = 0.25\nparam.rate = 4\n")
            .unwrap();
        assert_eq!(c.scenario.as_deref(), Some("bursty"));
        assert_eq!(
            c.scenario_params,
            vec![
                ("burst_ratio".to_string(), "0.25".to_string()),
                ("rate".to_string(), "4".to_string()),
            ]
        );
    }

    #[test]
    fn trace_replay_spelled_as_config_keys() {
        // `uwfq replay --config FILE` drives the trace entry through the
        // same scenario/param keys every other command uses.
        let mut c = Config::default();
        c.apply_lines(
            "scenario = trace\nparam.path = /data/google.csv\nparam.warmup = 1024\n\
             param.shape = true\n",
        )
        .unwrap();
        assert_eq!(c.scenario.as_deref(), Some("trace"));
        assert!(c
            .scenario_params
            .contains(&("path".to_string(), "/data/google.csv".to_string())));
        assert!(c.scenario_params.contains(&("warmup".to_string(), "1024".to_string())));
    }

    #[test]
    fn fault_knobs_parse_and_validate() {
        let mut c = Config::default();
        assert!(!c.fault.enabled(), "faults must default to off");
        c.apply_lines(
            "fault.task_fail_prob = 0.05\nfault.max_failures = 5\nfault.crash_mttf_s = 120\n",
        )
        .unwrap();
        assert!(c.fault.enabled());
        assert_eq!(c.fault.task_fail_prob, 0.05);
        assert_eq!(c.fault.max_failures, 5);
        assert_eq!(c.fault.crash_mttf_s, 120.0);
        // Out-of-range values are rejected with the knob named.
        let err = c.apply_lines("fault.task_fail_prob = 1.5").unwrap_err();
        assert!(err.contains("task_fail_prob"), "{err}");
        // Unknown fault knobs list the valid ones.
        let err = c.apply_lines("fault.bogus = 1").unwrap_err();
        assert!(err.contains("unknown fault knob"), "{err}");
        assert!(err.contains("straggler_prob"), "{err}");
        // Malformed numbers name the offending key.
        let err = c.apply_lines("fault.seed = abc").unwrap_err();
        assert!(err.contains("fault.seed") && err.contains("abc"), "{err}");
        let err = c.apply_lines("cores = abc").unwrap_err();
        assert!(err.contains("cores") && err.contains("abc"), "{err}");
    }

    #[test]
    fn shard_keys_parse_and_validate() {
        let mut c = Config::default();
        assert_eq!(c.shards, 1, "sharding must default off");
        assert_eq!(c.shard_epoch_s, 4.0);
        c.apply_lines("shards = 4\nshard_epoch_s = 2.5\n").unwrap();
        assert_eq!(c.shards, 4);
        assert_eq!(c.shard_epoch_s, 2.5);
        // Zero shards rejected, naming the threads composition rule.
        let err = c.apply_lines("shards = 0").unwrap_err();
        assert!(err.contains("shards") && err.contains("threads"), "{err}");
        let err = c.apply_lines("shard_epoch_s = 0").unwrap_err();
        assert!(err.contains("shard_epoch_s"), "{err}");
        let err = c.apply_lines("shard_epoch_s = -1").unwrap_err();
        assert!(err.contains("shard_epoch_s"), "{err}");
    }

    #[test]
    fn rebalance_keys_parse_and_validate() {
        let mut c = Config::default();
        assert!(!c.shard_rebalance, "lending must default off");
        assert_eq!(c.rebalance_min_cores, 1);
        assert_eq!(c.rebalance_cap, 2);
        c.apply_lines("shard_rebalance = true\nrebalance_min_cores = 2\nrebalance_cap = 4\n")
            .unwrap();
        assert!(c.shard_rebalance);
        assert_eq!(c.rebalance_min_cores, 2);
        assert_eq!(c.rebalance_cap, 4);
        c.apply_lines("shard_rebalance = 0").unwrap();
        assert!(!c.shard_rebalance);
        // Errors name the offending key.
        let err = c.apply_lines("shard_rebalance = maybe").unwrap_err();
        assert!(err.contains("shard_rebalance"), "{err}");
        let err = c.apply_lines("rebalance_min_cores = 0").unwrap_err();
        assert!(err.contains("rebalance_min_cores"), "{err}");
        let err = c.apply_lines("rebalance_cap = 0").unwrap_err();
        assert!(err.contains("rebalance_cap"), "{err}");
        let err = c.apply_lines("rebalance_cap = abc").unwrap_err();
        assert!(err.contains("rebalance_cap") && err.contains("abc"), "{err}");
    }

    #[test]
    fn bopf_and_new_policies_parse_and_validate() {
        let mut c = Config::default();
        assert_eq!(c.bopf_burst_rsec, 10.0);
        c.apply_lines("policy = drf").unwrap();
        assert_eq!(c.policy, PolicyKind::Drf);
        c.apply_lines("policy = bopf\nbopf_burst_rsec = 4.5\n").unwrap();
        assert_eq!(c.policy, PolicyKind::Bopf);
        assert_eq!(c.bopf_burst_rsec, 4.5);
        for bad in ["0", "-3", "inf", "nan"] {
            let err = c.apply_lines(&format!("bopf_burst_rsec = {bad}")).unwrap_err();
            assert!(err.contains("bopf_burst_rsec"), "{err}");
        }
        // The policy error lists the new names.
        let err = c.apply_lines("policy = zzz").unwrap_err();
        assert!(err.contains("drf") && err.contains("bopf"), "{err}");
    }

    #[test]
    fn label_includes_partitioner() {
        let c = Config::default()
            .with_policy(PolicyKind::Uwfq)
            .with_scheme(SchemeKind::Runtime);
        assert_eq!(c.label(), "UWFQ-P");
        assert_eq!(Config::default().with_policy(PolicyKind::Fair).label(), "Fair");
    }
}
