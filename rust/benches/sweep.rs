//! Bench: the parallel sweep engine — macro-grid cells/s at 1 thread vs
//! N threads, plus a determinism cross-check, emitted to
//! `BENCH_sweep.json` (benchkit JsonSink) so the grid-throughput
//! trajectory is tracked across PRs next to `BENCH_hotpath.json`.
//!
//! * `SWEEP_THREADS=N` sets the parallel worker count (default:
//!   min(4, cores)). With N=1 only the sequential baseline is recorded —
//!   a second leg would duplicate it under colliding names.
//! * `SWEEP_QUICK=1` (or `HOTPATH_QUICK=1`) shrinks the macro workload
//!   for CI smoke runs.
//!
//! Run with `cargo bench --bench sweep`.

use uwfq::bench::{figures, macro_grid_cell_count, table1_grid_cell_count, tables};
use uwfq::config::Config;
use uwfq::sweep::{auto_threads, Sweep};
use uwfq::util::benchkit::{bench_n, black_box, JsonSink};
use uwfq::workload::ScenarioSpec;

fn main() {
    let quick =
        std::env::var("SWEEP_QUICK").is_ok() || std::env::var("HOTPATH_QUICK").is_ok();
    let threads = std::env::var("SWEEP_THREADS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .map(|n| auto_threads(Some(n)))
        .unwrap_or_else(|| auto_threads(None).min(4));
    let mut sink = JsonSink::new();

    let base = Config::default();
    let w = if quick {
        ScenarioSpec::new("gtrace")
            .with("window_s", "120")
            .with("users", "10")
            .with("heavy_users", "3")
            .workload(42)
            .unwrap()
    } else {
        figures::default_macro_workload(42)
    };
    let macro_cells = macro_grid_cell_count() as f64;
    println!(
        "# Sweep engine — macro grid (Table 2 + Fig 7 = {macro_cells} cells), {} jobs, {} threads{}",
        w.jobs.len(),
        threads,
        if quick { " (quick)" } else { "" }
    );

    // The macro grid. bench_n's one warmup iteration populates the
    // idle-response memo cache, so the timed 1-thread and N-thread
    // iterations measure identical work.
    let grid = |s: &Sweep| {
        black_box(tables::table2(&w, &base, s));
        black_box(figures::fig7(&w, &base, s));
    };
    let iters = if quick { 3 } else { 5 };
    let seq = Sweep::seq();
    let par = Sweep::new(threads);
    let r1 = bench_n("sweep/macro_grid_1t", iters, || grid(&seq));
    sink.record(&r1);
    let cells_1t = macro_cells / r1.mean.as_secs_f64().max(1e-9);
    sink.metric("sweep/threads", threads as f64);
    sink.metric("sweep/macro_grid_cells", macro_cells);
    sink.metric("sweep/cells_per_s_1t", cells_1t);
    if threads > 1 {
        let rn = bench_n(&format!("sweep/macro_grid_{threads}t"), iters, || grid(&par));
        sink.record(&rn);
        let cells_nt = macro_cells / rn.mean.as_secs_f64().max(1e-9);
        let speedup = cells_nt / cells_1t.max(1e-9);
        println!(
            "    → {cells_1t:.2} cells/s at 1 thread, {cells_nt:.2} cells/s at {threads} threads ({speedup:.2}× speedup)"
        );
        sink.metric(&format!("sweep/cells_per_s_{threads}t"), cells_nt);
        sink.metric("sweep/speedup_vs_1t", speedup);

        // Determinism cross-check on the timed grid (the
        // sweep_differential test covers every CSV byte; this catches
        // drift in the bench config itself).
        let a = tables::render_table2(&tables::table2(&w, &base, &seq));
        let b = tables::render_table2(&tables::table2(&w, &base, &par));
        assert_eq!(a, b, "parallel macro grid diverged from sequential");
    } else {
        println!("    → {cells_1t:.2} cells/s at 1 thread (no parallel leg)");
    }

    // Table 1 combined grid, same comparison.
    let t1_cells = table1_grid_cell_count() as f64;
    let r1 = bench_n("sweep/table1_grid_1t", iters, || {
        black_box(tables::table1(42, &base, &seq));
    });
    sink.record(&r1);
    sink.metric(
        "sweep/table1_cells_per_s_1t",
        t1_cells / r1.mean.as_secs_f64().max(1e-9),
    );
    if threads > 1 {
        let rn = bench_n(&format!("sweep/table1_grid_{threads}t"), iters, || {
            black_box(tables::table1(42, &base, &par));
        });
        sink.record(&rn);
        sink.metric(
            &format!("sweep/table1_cells_per_s_{threads}t"),
            t1_cells / rn.mean.as_secs_f64().max(1e-9),
        );
    }

    let (hits, misses) = uwfq::sim::idle_cache_stats();
    sink.metric("sweep/idle_cache_hits", hits as f64);
    sink.metric("sweep/idle_cache_misses", misses as f64);

    if let Err(e) = sink.write("BENCH_sweep.json") {
        eprintln!("warning: could not write BENCH_sweep.json: {e}");
    } else {
        println!("wrote BENCH_sweep.json");
    }
}
