//! Bench: the sharded million-user engine — jobs/s at increasing shard
//! counts on the 1M-job / 100k-user workload, with speedup vs the
//! 1-shard baseline and the observed virtual-time drift vs its provable
//! bound, emitted to `BENCH_shard.json` (benchkit JsonSink) so the
//! intra-run scaling trajectory is tracked across PRs next to
//! `BENCH_scale.json`.
//!
//! * `SHARD_JOBS` / `SHARD_USERS` / `SHARD_COUNTS` (comma-separated)
//!   override the workload size and shard-count sweep.
//! * `SHARD_QUICK=1` (or `SCALE_QUICK=1`) shrinks to 50k jobs / 5k users
//!   for CI smoke runs.
//!
//! Run with `cargo bench --bench shard`.

use uwfq::bench::shard::{record_metrics, render, run_shard};
use uwfq::config::Config;
use uwfq::util::benchkit::JsonSink;
use uwfq::workload::stream::ScaleParams;

fn env_num<T: std::str::FromStr>(key: &str) -> Option<T> {
    std::env::var(key).ok().and_then(|v| v.parse().ok())
}

fn main() {
    let quick =
        std::env::var("SHARD_QUICK").is_ok() || std::env::var("SCALE_QUICK").is_ok();
    let jobs: u64 = env_num("SHARD_JOBS").unwrap_or(if quick { 50_000 } else { 1_000_000 });
    let users: u32 = env_num("SHARD_USERS").unwrap_or(if quick { 5_000 } else { 100_000 });
    let cfg = Config::default().with_cores(64);
    let counts: Vec<u32> = match std::env::var("SHARD_COUNTS") {
        Ok(v) => v
            .split(',')
            .filter_map(|s| s.trim().parse().ok())
            .filter(|&s| s >= 1 && s <= cfg.cores)
            .collect(),
        Err(_) => {
            let avail = std::thread::available_parallelism()
                .map(|n| n.get() as u32)
                .unwrap_or(1);
            [1u32, 2, 4, 8]
                .into_iter()
                .filter(|&s| s <= cfg.cores && s <= avail.max(2))
                .collect()
        }
    };
    let params = ScaleParams {
        users,
        jobs,
        cores: cfg.cores,
        target_utilization: 0.85,
        seed: cfg.seed,
    };

    println!(
        "# Sharded engine — {jobs} jobs / {users} users on {} cores, shard counts {counts:?}{}",
        cfg.cores,
        if quick { " (quick)" } else { "" }
    );
    let outcome = run_shard(&params, &cfg, &counts);
    print!("{}", render(&outcome));

    let mut sink = JsonSink::new();
    record_metrics(&outcome, &mut sink);
    if let Err(e) = sink.write("BENCH_shard.json") {
        eprintln!("warning: could not write BENCH_shard.json: {e}");
    } else {
        println!("wrote BENCH_shard.json");
    }

    // The drift bound is part of the bench contract: a sync-barrier
    // regression would otherwise ship plausible-looking speedups.
    for r in &outcome.rows {
        if r.max_drift_rsec > r.bound_rsec + 1e-9 {
            eprintln!(
                "S={}: virtual-time drift {} exceeds bound {}",
                r.shards, r.max_drift_rsec, r.bound_rsec
            );
            std::process::exit(1);
        }
    }
    println!("virtual-time drift within the provable bound on every row");
}
