//! Bench: the streaming scale pipeline — jobs/s and task-events/s on the
//! million-job / ten-thousand-user workload, with streaming-vs-exact
//! quantile error columns, emitted to `BENCH_scale.json` (benchkit
//! JsonSink) so the memory-bounded throughput trajectory is tracked
//! across PRs next to `BENCH_hotpath.json` / `BENCH_sweep.json`.
//!
//! * `SCALE_JOBS` / `SCALE_USERS` override the workload size.
//! * `SCALE_QUICK=1` (or `HOTPATH_QUICK=1`) shrinks to 50k jobs / 1k
//!   users for CI smoke runs.
//!
//! Run with `cargo bench --bench scale`.

use uwfq::bench::scale::{record_metrics, render, run_scale};
use uwfq::config::Config;
use uwfq::util::benchkit::JsonSink;
use uwfq::workload::stream::ScaleParams;

fn env_num<T: std::str::FromStr>(key: &str) -> Option<T> {
    std::env::var(key).ok().and_then(|v| v.parse().ok())
}

fn main() {
    let quick =
        std::env::var("SCALE_QUICK").is_ok() || std::env::var("HOTPATH_QUICK").is_ok();
    let jobs: u64 = env_num("SCALE_JOBS").unwrap_or(if quick { 50_000 } else { 1_000_000 });
    let users: u32 = env_num("SCALE_USERS").unwrap_or(if quick { 1_000 } else { 10_000 });
    let cfg = Config::default().with_cores(64);
    let params = ScaleParams {
        users,
        jobs,
        cores: cfg.cores,
        target_utilization: 0.85,
        seed: cfg.seed,
    };

    println!(
        "# Streaming scale pipeline — {jobs} jobs / {users} users on {} cores{}",
        cfg.cores,
        if quick { " (quick)" } else { "" }
    );
    let outcome = run_scale(&params, &cfg, true);
    print!("{}", render(&outcome));

    let mut sink = JsonSink::new();
    record_metrics(&outcome, &mut sink);
    if let Err(e) = sink.write("BENCH_scale.json") {
        eprintln!("warning: could not write BENCH_scale.json: {e}");
    } else {
        println!("wrote BENCH_scale.json");
    }

    // The accuracy contract is part of the bench: a silent estimator
    // regression would otherwise ship plausible-looking numbers.
    if let Some(v) = &outcome.verify {
        if let Err(e) = v.check() {
            eprintln!("streaming accuracy outside documented tolerance: {e}");
            std::process::exit(1);
        }
        println!("streaming estimators within documented tolerance");
    }
}
