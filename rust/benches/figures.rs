//! Bench: regenerate every figure (3–7) and time each generation (all
//! figure grids route through the sweep engine; sequential here, see
//! `--bench sweep` for the parallel timings).
//! Run with `cargo bench --bench figures`.

use uwfq::bench::figures;
use uwfq::config::Config;
use uwfq::sweep::Sweep;
use uwfq::util::benchkit::{bench_n, black_box};

fn main() {
    let base = Config::default();
    let seq = Sweep::seq();
    bench_n("figures/fig3_skew", 10, || {
        black_box(figures::fig3(&base, &seq));
    });
    bench_n("figures/fig4_inversion", 10, || {
        black_box(figures::fig4(&base, &seq));
    });
    bench_n("figures/fig5_cdf_scenario1", 3, || {
        black_box(figures::fig5(42, &base, &seq));
    });
    bench_n("figures/fig6_cdf_scenario2", 3, || {
        black_box(figures::fig6(42, &base, &seq));
    });
    let w = figures::default_macro_workload(42);
    bench_n("figures/fig7_user_violations", 3, || {
        black_box(figures::fig7(&w, &base, &seq));
    });

    // Print the headline numbers.
    let f3 = figures::fig3(&base, &seq);
    println!("\nFig 3 completion: {} {:.2}s vs {} {:.2}s",
        f3.runs[0].0, f3.runs[0].1, f3.runs[1].0, f3.runs[1].1);
    let f4 = figures::fig4(&base, &seq);
    println!("Fig 4 high-prio RT: {} {:.2}s vs {} {:.2}s",
        f4.runs[0].0, f4.runs[0].1, f4.runs[1].0, f4.runs[1].1);
}
