//! Bench: regenerate every figure (3–7) and time each generation.
//! Run with `cargo bench --bench figures`.

use uwfq::bench::figures;
use uwfq::config::Config;
use uwfq::util::benchkit::{bench_n, black_box};

fn main() {
    let base = Config::default();
    bench_n("figures/fig3_skew", 10, || {
        black_box(figures::fig3(&base));
    });
    bench_n("figures/fig4_inversion", 10, || {
        black_box(figures::fig4(&base));
    });
    bench_n("figures/fig5_cdf_scenario1", 3, || {
        black_box(figures::fig5(42, &base));
    });
    bench_n("figures/fig6_cdf_scenario2", 3, || {
        black_box(figures::fig6(42, &base));
    });
    let w = figures::default_macro_workload(42);
    bench_n("figures/fig7_user_violations", 3, || {
        black_box(figures::fig7(&w, &base));
    });

    // Print the headline numbers.
    let f3 = figures::fig3(&base);
    println!("\nFig 3 completion: {} {:.2}s vs {} {:.2}s",
        f3.runs[0].0, f3.runs[0].1, f3.runs[1].0, f3.runs[1].1);
    let f4 = figures::fig4(&base);
    println!("Fig 4 high-prio RT: {} {:.2}s vs {} {:.2}s",
        f4.runs[0].0, f4.runs[0].1, f4.runs[1].0, f4.runs[1].1);
}
