//! Bench: the streaming trace-replay pipeline — write a synthetic trace
//! with the seeded writer, replay it through the one-pass §5.3 shaper and
//! the simulator, and emit throughput + resident-state counters to
//! `BENCH_replay.json` (benchkit JsonSink) so the trace path's trajectory
//! is tracked across PRs next to `BENCH_scale.json`.
//!
//! * `REPLAY_JOBS` overrides the synthetic trace's row count.
//! * `REPLAY_QUICK=1` (or `HOTPATH_QUICK=1`) shrinks to 20k rows for CI
//!   smoke runs (default 200k).
//!
//! Run with `cargo bench --bench replay`.

use uwfq::bench::replay::{record_metrics, render, run_replay};
use uwfq::config::Config;
use uwfq::util::benchkit::JsonSink;
use uwfq::workload::gtrace::GtraceParams;
use uwfq::workload::traceio::{writer, ShapeParams, TraceParams};

fn main() {
    let quick = std::env::var("REPLAY_QUICK").is_ok() || std::env::var("HOTPATH_QUICK").is_ok();
    let jobs: u64 = std::env::var("REPLAY_JOBS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(if quick { 20_000 } else { 200_000 });
    let cfg = Config::default().with_cores(32);

    let dir = std::env::temp_dir().join(format!("uwfq_bench_replay_{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("temp dir");
    let path = dir.join("synth.csv").to_str().expect("utf8 path").to_string();

    // Sub-critical load keeps the backlog (and therefore the in-flight
    // counter) bounded — the property this bench exists to measure.
    let gp = writer::params_for_jobs(
        jobs,
        &GtraceParams {
            cores: cfg.cores,
            target_utilization: 0.8,
            ..GtraceParams::default()
        },
    );
    let rows = writer::write_synthetic(&path, cfg.seed, &gp).expect("write trace");
    println!(
        "# Streaming trace replay — {rows} rows on {} cores{}",
        cfg.cores,
        if quick { " (quick)" } else { "" }
    );

    let tp = TraceParams {
        path,
        shaping: ShapeParams {
            cores: cfg.cores,
            target_utilization: 0.8,
            ..ShapeParams::default()
        },
        seed: cfg.seed,
        ..TraceParams::default()
    };
    let outcome = run_replay(&tp, &cfg).expect("replay");
    print!("{}", render(&outcome));

    let mut sink = JsonSink::new();
    record_metrics(&outcome, &mut sink);
    if let Err(e) = sink.write("BENCH_replay.json") {
        eprintln!("warning: could not write BENCH_replay.json: {e}");
    } else {
        println!("wrote BENCH_replay.json");
    }
    std::fs::remove_dir_all(&dir).ok();

    // The bounded-state contract is part of the bench: a regression that
    // starts materializing the trace would otherwise ship unnoticed.
    if outcome.max_buffered_rows > tp.shaping.warmup {
        eprintln!(
            "replay buffered {} rows, above the {}-row warmup bound",
            outcome.max_buffered_rows, tp.shaping.warmup
        );
        std::process::exit(1);
    }
}
