//! Bench: regenerate Table 1 (micro scenarios 1–2, §5.2.2) end to end and
//! time the full experiment grid — sequential and through the parallel
//! sweep engine. Run with `cargo bench --bench table1`.

use std::time::Duration;

use uwfq::bench::tables;
use uwfq::config::Config;
use uwfq::sweep::{auto_threads, Sweep};
use uwfq::util::benchkit::{bench_n, black_box};

fn main() {
    let base = Config::default();
    let threads = auto_threads(None).min(4);
    println!("# Table 1 — end-to-end experiment grid (4 schedulers × 2 scenarios)");
    bench_n("table1/full_grid_1t", 5, || {
        black_box(tables::table1(42, &base, &Sweep::seq()));
    });
    if threads > 1 {
        bench_n(&format!("table1/full_grid_{threads}t"), 5, || {
            black_box(tables::table1(42, &base, &Sweep::new(threads)));
        });
    }

    // Per-scenario breakdown (registry entries with paper defaults).
    let s1 = uwfq::workload::registry::builtin_workload("scenario1", 42);
    let s2 = uwfq::workload::registry::builtin_workload("scenario2", 42);
    bench_n("table1/scenario1_grid", 5, || {
        black_box(tables::table1_scenario(&s1, &base, true, &Sweep::seq()));
    });
    bench_n("table1/scenario2_grid", 5, || {
        black_box(tables::table1_scenario(&s2, &base, false, &Sweep::seq()));
    });

    // One full scenario-1 simulation per scheduler (the unit the grid
    // repeats).
    for policy in uwfq::sched::PolicyKind::PAPER {
        let cfg = base.clone().with_policy(policy);
        let jobs = s1.jobs.clone();
        uwfq::util::benchkit::bench(
            &format!("table1/sim_scenario1/{}", policy.name()),
            Duration::from_secs(2),
            || {
                black_box(uwfq::sim::simulate(cfg.clone(), jobs.clone()));
            },
        );
    }

    // And the resulting table, printed once for reference.
    let (t1, t2) = tables::table1(42, &base, &Sweep::seq());
    println!("\n{}", tables::render_table1(&t1));
    println!("{}", tables::render_table1(&t2));
}
