//! Bench: regenerate Table 2 (macro benchmark, §5.3.1) end to end —
//! sequential and through the parallel sweep engine.
//! Run with `cargo bench --bench table2`.

use std::time::Duration;

use uwfq::bench::{figures, tables};
use uwfq::config::Config;
use uwfq::sweep::{auto_threads, Sweep};
use uwfq::util::benchkit::{bench, bench_n, black_box};

fn main() {
    let base = Config::default();
    let threads = auto_threads(None).min(4);
    let w = figures::default_macro_workload(42);
    println!(
        "# Table 2 — macro workload: {} jobs, {} users, {:.0} core-s",
        w.jobs.len(),
        w.users().len(),
        w.total_slot_time()
    );

    bench_n("table2/full_grid_8_runs_1t", 3, || {
        black_box(tables::table2(&w, &base, &Sweep::seq()));
    });
    if threads > 1 {
        bench_n(&format!("table2/full_grid_8_runs_{threads}t"), 3, || {
            black_box(tables::table2(&w, &base, &Sweep::new(threads)));
        });
    }

    // Single 500 s macro simulation per scheduler (the simulator's
    // end-to-end unit; the paper needed ~10 wall-minutes per run).
    for policy in uwfq::sched::PolicyKind::PAPER {
        let cfg = base.clone().with_policy(policy);
        let jobs = w.jobs.clone();
        bench(
            &format!("table2/sim_macro/{}", policy.name()),
            Duration::from_secs(2),
            || {
                black_box(uwfq::sim::simulate(cfg.clone(), jobs.clone()));
            },
        );
    }

    let t2 = tables::table2(&w, &base, &Sweep::seq());
    println!("\n{}", tables::render_table2(&t2));
}
