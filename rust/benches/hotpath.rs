//! Bench: L3 scheduler hot paths — the per-event costs the paper bounds
//! to O(log N) (§6.1 virtual time) plus the per-offer selection cost.
//! Run with `cargo bench --bench hotpath`. These feed EXPERIMENTS.md §Perf
//! and emit `BENCH_hotpath.json` (benchkit JsonSink) so the perf
//! trajectory is tracked across PRs.
//!
//! Scaling cases: `sim_200jobs` (the historical baseline), `burst400` vs
//! `burst4000` (per-event cost must grow sub-linearly in active-stage
//! count now that selection is incremental), and `sim_50k` — 50 000 jobs
//! / 100 users / 64 cores, reporting task-events/s per policy. The 50k
//! case also runs the event-core ablation (heap per-event vs calendar
//! wheel, batching on/off — all byte-identical schedules, so the ratios
//! are pure event-core cost).
//!
//! `HOTPATH_QUICK=1` shrinks the large cases for CI smoke runs.

use std::time::Duration;

use uwfq::config::Config;
use uwfq::core::job::JobSpec;
use uwfq::sched::vtime::{SingleVtime, TwoLevelVtime};
use uwfq::sched::PolicyKind;
use uwfq::sim;
use uwfq::sim::{EventBackend, SimOpts};
use uwfq::util::benchkit::{bench, bench_n, black_box, JsonSink};
use uwfq::util::Rng;

/// Deadline assignment (Algorithm 1 + 2 + 3) cost at a given number of
/// active users/jobs in the virtual system.
fn bench_deadline_assignment(sink: &mut JsonSink, users: u64, backlog: usize) {
    let mut rng = Rng::new(7);
    // Pre-populate.
    let mut vt = TwoLevelVtime::new(32.0);
    let mut t = 0.0;
    let mut id = 0u64;
    for _ in 0..backlog {
        t += 0.001;
        vt.job_arrival(t, rng.below(users) as u32, id, 1.0 + rng.f64() * 100.0, 1.0, 2.0);
        id += 1;
    }
    let r = bench(
        &format!("hotpath/alg1_job_arrival/u{users}_jobs{backlog}"),
        Duration::from_millis(600),
        || {
            t += 0.0005;
            vt.job_arrival(t, rng.below(users) as u32, id, 5.0, 1.0, 2.0);
            id += 1;
        },
    );
    sink.record(&r);
}

/// One-level virtual time (CFQ stage arrival) at a given backlog — the
/// regression case for the heap-backed retirement (the seed's sorted-Vec
/// `remove(0)` was O(n) per retirement).
fn bench_cfq_arrival(sink: &mut JsonSink, backlog: usize) {
    let mut v = SingleVtime::new(32.0);
    let mut rng = Rng::new(3);
    let mut t = 0.0;
    let mut id = 0u64;
    for _ in 0..backlog {
        t += 0.001;
        v.arrive(t, id, 1.0 + rng.f64() * 50.0);
        id += 1;
    }
    let r = bench(
        &format!("hotpath/cfq_stage_arrival/{backlog}_active"),
        Duration::from_millis(400),
        || {
            t += 0.0005;
            v.arrive(t, id, 10.0);
            id += 1;
        },
    );
    sink.record(&r);
}

/// A congested multi-user workload: `n` jobs over `users` users arriving
/// every `gap_us`.
fn workload(n: usize, users: u32, gap_us: u64) -> Vec<JobSpec> {
    (0..n)
        .map(|i| {
            JobSpec::three_phase(
                (i as u32) % users,
                &format!("j{i}"),
                (i as u64) * gap_us,
                2.0,
                128 << 20,
                4,
                None,
            )
        })
        .collect()
}

/// End-to-end simulator throughput for one policy; records task-events/s.
fn bench_sim(
    sink: &mut JsonSink,
    label: &str,
    cfg: &Config,
    jobs: &[JobSpec],
    policy: PolicyKind,
    iters: u64,
) {
    // Count task events once (one logged probe run).
    let mut probe = cfg.clone();
    probe.log_tasks = true;
    let tasks = sim::simulate(probe.with_policy(policy), jobs.to_vec())
        .task_log
        .len();
    let c = cfg.clone().with_policy(policy);
    let name = format!("hotpath/{label}/{}", policy.name());
    let r = bench_n(&name, iters, || {
        black_box(sim::simulate(c.clone(), jobs.to_vec()));
    });
    let ev_per_s = tasks as f64 / r.mean.as_secs_f64();
    println!("    → {:.2} M task-events/s ({tasks} tasks/run)", ev_per_s / 1e6);
    sink.record(&r);
    sink.metric(&format!("{name}/task_events_per_s"), ev_per_s);
}

fn main() {
    let quick = std::env::var("HOTPATH_QUICK").is_ok();
    let mut sink = JsonSink::new();
    println!("# L3 hot paths{}", if quick { " (quick)" } else { "" });

    // Algorithm 1-3: job arrival → deadline assignment, scaling in users
    // and virtual backlog.
    for (users, backlog) in [(4u64, 16usize), (25, 100), (100, 1000), (500, 5000)] {
        bench_deadline_assignment(&mut sink, users, backlog);
    }

    // Classic virtual time (CFQ stage arrival), incl. the 10k-entity
    // regression case for heap-backed retirement.
    bench_cfq_arrival(&mut sink, 1000);
    bench_cfq_arrival(&mut sink, 10_000);

    // Full simulator throughput: events/second on a congested workload.
    {
        let cfg = Config {
            task_overhead: 0.005,
            ..Config::default()
        };
        let jobs = workload(200, 10, 50_000);
        for policy in PolicyKind::ALL {
            bench_sim(&mut sink, "sim_200jobs", &cfg, &jobs, policy, 8);
        }
    }

    // Offer-path selection cost at high active-stage counts: per-event
    // cost must grow sub-linearly from burst400 to burst4000.
    {
        let cfg = Config {
            task_overhead: 0.001,
            ..Config::default()
        };
        let burst = |n: usize| -> Vec<JobSpec> {
            (0..n)
                .map(|i| {
                    JobSpec::three_phase(
                        (i % 25) as u32,
                        &format!("q{i}"),
                        0,
                        1.0,
                        128 << 20,
                        4,
                        None,
                    )
                })
                .collect()
        };
        for policy in [PolicyKind::Fair, PolicyKind::Ujf, PolicyKind::Uwfq] {
            bench_sim(&mut sink, "burst400", &cfg, &burst(400), policy, 4);
        }
        let big = if quick { 1000 } else { 4000 };
        for policy in [PolicyKind::Fair, PolicyKind::Ujf, PolicyKind::Uwfq] {
            bench_sim(&mut sink, &format!("burst{big}"), &cfg, &burst(big), policy, 2);
        }
    }

    // Large-scale throughput: 50k jobs / 100 users / 64 cores.
    {
        let mut cfg = Config::default().with_cores(64);
        cfg.task_overhead = 0.005;
        let n = if quick { 2_000 } else { 50_000 };
        let jobs = workload(n, 100, 4_000);
        for policy in PolicyKind::ALL {
            bench_sim(&mut sink, &format!("sim_{n}jobs_100users_64cores"), &cfg, &jobs, policy, 2);
        }

        // Event-core ablation on the same case: queue structure and
        // batching isolated (schedules are byte-identical across arms).
        let arms = [
            ("heap_perevent", SimOpts { backend: EventBackend::Heap, batch: false }),
            ("heap_batched", SimOpts { backend: EventBackend::Heap, batch: true }),
            ("wheel_perevent", SimOpts { backend: EventBackend::Wheel, batch: false }),
            ("wheel_batched", SimOpts { backend: EventBackend::Wheel, batch: true }),
        ];
        for policy in [PolicyKind::Fifo, PolicyKind::Uwfq] {
            for (arm, opts) in arms {
                let c = cfg.clone().with_policy(policy);
                let name = format!("hotpath/eventcore_{n}jobs/{}/{arm}", policy.name());
                let r = bench_n(&name, 2, || {
                    black_box(sim::simulate_opts(c.clone(), jobs.to_vec(), opts));
                });
                sink.record(&r);
            }
        }
    }

    if let Err(e) = sink.write("BENCH_hotpath.json") {
        eprintln!("warning: could not write BENCH_hotpath.json: {e}");
    } else {
        println!("wrote BENCH_hotpath.json");
    }
}
