//! Bench: L3 scheduler hot paths — the per-event costs the paper bounds
//! to O(log N) (§6.1 virtual time) plus the per-offer selection cost.
//! Run with `cargo bench --bench hotpath`. These feed EXPERIMENTS.md §Perf.

use std::time::Duration;

use uwfq::config::Config;
use uwfq::core::job::JobSpec;
use uwfq::sched::vtime::{SingleVtime, TwoLevelVtime};
use uwfq::sched::{PolicyKind};
use uwfq::sim;
use uwfq::util::benchkit::{bench, black_box};
use uwfq::util::Rng;

/// Deadline assignment (Algorithm 1 + 2 + 3) cost at a given number of
/// active users/jobs in the virtual system.
fn bench_deadline_assignment(users: u64, backlog: usize) {
    let mut rng = Rng::new(7);
    // Pre-populate.
    let mut vt = TwoLevelVtime::new(32.0);
    let mut t = 0.0;
    let mut id = 0u64;
    for _ in 0..backlog {
        t += 0.001;
        vt.job_arrival(t, rng.below(users) as u32, id, 1.0 + rng.f64() * 100.0, 1.0, 2.0);
        id += 1;
    }
    bench(
        &format!("hotpath/alg1_job_arrival/u{users}_jobs{backlog}"),
        Duration::from_millis(600),
        || {
            t += 0.0005;
            vt.job_arrival(t, rng.below(users) as u32, id, 5.0, 1.0, 2.0);
            id += 1;
        },
    );
}

fn main() {
    println!("# L3 hot paths");

    // Algorithm 1-3: job arrival → deadline assignment, scaling in users
    // and virtual backlog.
    for (users, backlog) in [(4u64, 16usize), (25, 100), (100, 1000), (500, 5000)] {
        bench_deadline_assignment(users, backlog);
    }

    // Classic virtual time (CFQ stage arrival).
    {
        let mut v = SingleVtime::new(32.0);
        let mut rng = Rng::new(3);
        let mut t = 0.0;
        let mut id = 0u64;
        for _ in 0..1000 {
            t += 0.001;
            v.arrive(t, id, 1.0 + rng.f64() * 50.0);
            id += 1;
        }
        bench("hotpath/cfq_stage_arrival/1000_active", Duration::from_millis(400), || {
            t += 0.0005;
            v.arrive(t, id, 10.0);
            id += 1;
        });
    }

    // Full simulator throughput: events/second on a congested workload.
    {
        let mut cfg = Config::default();
        cfg.task_overhead = 0.005;
        let jobs: Vec<JobSpec> = (0..200)
            .map(|i| {
                JobSpec::three_phase(
                    (i % 10) as u32,
                    &format!("j{i}"),
                    (i as u64) * 50_000,
                    2.0,
                    128 << 20,
                    4,
                    None,
                )
            })
            .collect();
        // Count events once.
        let mut probe = cfg.clone();
        probe.log_tasks = true;
        let rep = sim::simulate(probe.with_policy(PolicyKind::Uwfq), jobs.clone());
        let tasks = rep.task_log.len();
        for policy in PolicyKind::ALL {
            let c = cfg.clone().with_policy(policy);
            let r = bench(
                &format!("hotpath/sim_200jobs/{}", policy.name()),
                Duration::from_secs(1),
                || {
                    black_box(sim::simulate(c.clone(), jobs.clone()));
                },
            );
            let ev_per_s = tasks as f64 / r.mean.as_secs_f64();
            println!("    → {:.2} M task-events/s ({tasks} tasks/run)", ev_per_s / 1e6);
        }
    }

    // Offer-path selection cost at high active-stage counts.
    {
        let mut cfg = Config::default();
        cfg.task_overhead = 0.001;
        let jobs: Vec<JobSpec> = (0..400)
            .map(|i| {
                JobSpec::three_phase((i % 25) as u32, &format!("q{i}"), 0, 1.0, 128 << 20, 4, None)
            })
            .collect();
        for policy in [PolicyKind::Fair, PolicyKind::Ujf, PolicyKind::Uwfq] {
            let c = cfg.clone().with_policy(policy);
            bench(
                &format!("hotpath/burst400/{}", policy.name()),
                Duration::from_secs(1),
                || {
                    black_box(sim::simulate(c.clone(), jobs.clone()));
                },
            );
        }
    }
}
