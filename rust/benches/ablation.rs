//! Bench: design-choice ablations (DESIGN.md §5).
//!
//! * scheduler context: CFQ (job deadlines only) vs UJF (user fairness
//!   only) vs UWFQ (both) on scenario 1;
//! * ATR sensitivity sweep (§3.2 "ATR should not be set too low");
//! * grace-period sweep (§4.2);
//! * estimator-error sweep (§6.4 robustness).
//!
//! Every ablation grid runs as cells on the parallel sweep engine.
//! Run with `cargo bench --bench ablation`.

use uwfq::bench::{run_one_in, run_one};
use uwfq::config::Config;
use uwfq::partition::SchemeKind;
use uwfq::sched::PolicyKind;
use uwfq::sweep::{auto_threads, Sweep};
use uwfq::util::benchkit::bench_n;

fn main() {
    let base = Config::default();
    let swp = Sweep::new(auto_threads(None).min(4));

    println!("# Ablation 1 — scheduler context (scenario 1, infrequent-user RT)");
    let w1 = uwfq::workload::registry::builtin_workload("scenario1", 42);
    let ctx_cells: Vec<Config> = [PolicyKind::Cfq, PolicyKind::Ujf, PolicyKind::Uwfq]
        .into_iter()
        .map(|p| base.clone().with_policy(p))
        .collect();
    let ctx_metrics = swp.run(&ctx_cells, |ctx, cfg| run_one_in(ctx, cfg, &w1));
    for m in &ctx_metrics {
        println!(
            "  {:<6} avg RT {:>6.2} s   infreq RT {:>6.2} s",
            m.label,
            m.mean_rt(),
            m.mean_rt_by_class(uwfq::workload::UserClass::Infrequent)
        );
    }

    println!("\n# Ablation 2 — ATR sensitivity (macro, UWFQ-P)");
    let wm = uwfq::workload::ScenarioSpec::new("gtrace")
        .with("window_s", "200")
        .with("users", "15")
        .with("heavy_users", "4")
        .workload(42)
        .unwrap();
    let atrs = [0.05, 0.1, 0.25, 0.5, 1.0, 2.0, 5.0, 10.0];
    let atr_cells: Vec<Config> = atrs
        .iter()
        .map(|&atr| {
            let mut cfg = base
                .clone()
                .with_policy(PolicyKind::Uwfq)
                .with_scheme(SchemeKind::Runtime);
            cfg.atr = atr;
            cfg
        })
        .collect();
    let atr_metrics = swp.run(&atr_cells, |ctx, cfg| run_one_in(ctx, cfg, &wm));
    for (atr, m) in atrs.iter().zip(&atr_metrics) {
        println!(
            "  ATR {atr:>6.2} s → avg RT {:>6.2} s   makespan {:>6.1} s",
            m.mean_rt(),
            m.makespan_s
        );
    }

    println!("\n# Ablation 3 — grace period (scenario 1, UWFQ)");
    let graces = [0.0, 0.5, 2.0, 8.0, 32.0];
    let grace_cells: Vec<Config> = graces
        .iter()
        .map(|&g| {
            let mut cfg = base.clone().with_policy(PolicyKind::Uwfq);
            cfg.grace_rsec = g;
            cfg
        })
        .collect();
    let grace_metrics = swp.run(&grace_cells, |ctx, cfg| run_one_in(ctx, cfg, &w1));
    for (grace, m) in graces.iter().zip(&grace_metrics) {
        println!(
            "  grace {grace:>5.1} rs → avg RT {:>6.2} s   infreq {:>6.2} s",
            m.mean_rt(),
            m.mean_rt_by_class(uwfq::workload::UserClass::Infrequent)
        );
    }

    println!("\n# Ablation 4 — estimator error (scenario 1, UWFQ)");
    let sigmas = [0.0, 0.2, 0.5, 1.0];
    let sigma_cells: Vec<Config> = sigmas
        .iter()
        .map(|&s| {
            let mut cfg = base.clone().with_policy(PolicyKind::Uwfq);
            cfg.estimator_sigma = s;
            cfg
        })
        .collect();
    let sigma_metrics = swp.run(&sigma_cells, |ctx, cfg| run_one_in(ctx, cfg, &w1));
    for (sigma, m) in sigmas.iter().zip(&sigma_metrics) {
        println!("  sigma {sigma:>4.1} → avg RT {:>6.2} s", m.mean_rt());
    }

    println!("\n# Timing: one ablation grid");
    bench_n("ablation/atr_sweep_3_points", 2, || {
        for atr in [0.1, 0.5, 2.0] {
            let mut cfg = base
                .clone()
                .with_policy(PolicyKind::Uwfq)
                .with_scheme(SchemeKind::Runtime);
            cfg.atr = atr;
            uwfq::util::benchkit::black_box(run_one(&cfg, &wm));
        }
    });
}
