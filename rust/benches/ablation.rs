//! Bench: design-choice ablations (DESIGN.md §5).
//!
//! * scheduler context: CFQ (job deadlines only) vs UJF (user fairness
//!   only) vs UWFQ (both) on scenario 1;
//! * ATR sensitivity sweep (§3.2 "ATR should not be set too low");
//! * grace-period sweep (§4.2);
//! * estimator-error sweep (§6.4 robustness).
//!
//! Run with `cargo bench --bench ablation`.

use uwfq::bench::run_one;
use uwfq::config::Config;
use uwfq::partition::SchemeKind;
use uwfq::sched::PolicyKind;
use uwfq::util::benchkit::bench_n;
use uwfq::workload::{gtrace, scenarios};

fn main() {
    let base = Config::default();

    println!("# Ablation 1 — scheduler context (scenario 1, infrequent-user RT)");
    let w1 = scenarios::scenario1_default(42);
    for policy in [PolicyKind::Cfq, PolicyKind::Ujf, PolicyKind::Uwfq] {
        let m = run_one(&base.clone().with_policy(policy), &w1);
        println!(
            "  {:<5} avg RT {:>6.2} s   infreq RT {:>6.2} s",
            policy.name(),
            m.mean_rt(),
            m.mean_rt_by_class(uwfq::workload::UserClass::Infrequent)
        );
    }

    println!("\n# Ablation 2 — ATR sensitivity (macro, UWFQ-P)");
    let mut p = gtrace::GtraceParams::default();
    p.window_s = 200.0;
    p.users = 15;
    p.heavy_users = 4;
    let wm = gtrace::gtrace(42, &p);
    for atr in [0.05, 0.1, 0.25, 0.5, 1.0, 2.0, 5.0, 10.0] {
        let mut cfg = base
            .clone()
            .with_policy(PolicyKind::Uwfq)
            .with_scheme(SchemeKind::Runtime);
        cfg.atr = atr;
        let m = run_one(&cfg, &wm);
        println!(
            "  ATR {atr:>6.2} s → avg RT {:>6.2} s   makespan {:>6.1} s",
            m.mean_rt(),
            m.makespan_s
        );
    }

    println!("\n# Ablation 3 — grace period (scenario 1, UWFQ)");
    for grace in [0.0, 0.5, 2.0, 8.0, 32.0] {
        let mut cfg = base.clone().with_policy(PolicyKind::Uwfq);
        cfg.grace_rsec = grace;
        let m = run_one(&cfg, &w1);
        println!(
            "  grace {grace:>5.1} rs → avg RT {:>6.2} s   infreq {:>6.2} s",
            m.mean_rt(),
            m.mean_rt_by_class(uwfq::workload::UserClass::Infrequent)
        );
    }

    println!("\n# Ablation 4 — estimator error (scenario 1, UWFQ)");
    for sigma in [0.0, 0.2, 0.5, 1.0] {
        let mut cfg = base.clone().with_policy(PolicyKind::Uwfq);
        cfg.estimator_sigma = sigma;
        let m = run_one(&cfg, &w1);
        println!("  sigma {sigma:>4.1} → avg RT {:>6.2} s", m.mean_rt());
    }

    println!("\n# Timing: one ablation grid");
    bench_n("ablation/atr_sweep_8_points", 2, || {
        for atr in [0.1, 0.5, 2.0] {
            let mut cfg = base
                .clone()
                .with_policy(PolicyKind::Uwfq)
                .with_scheme(SchemeKind::Runtime);
            cfg.atr = atr;
            uwfq::util::benchkit::black_box(run_one(&cfg, &wm));
        }
    });
}
