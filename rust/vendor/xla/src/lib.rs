//! Offline stub of the `xla` (PJRT) binding surface this repository
//! uses. The simulator, schedulers, benches and metrics are fully
//! functional without it; only the *real execution backend*
//! (`uwfq serve`, `rust/tests/runtime_roundtrip.rs`) needs actual PJRT,
//! and those paths degrade to a clear runtime error here.
//!
//! Swap in the real bindings by replacing the `xla` path dependency in
//! `rust/Cargo.toml` — the types below mirror the real crate's names and
//! signatures exactly as far as they are used.

use std::fmt;

#[derive(Debug)]
pub struct Error(String);

impl Error {
    fn unavailable(what: &str) -> Error {
        Error(format!(
            "{what}: PJRT backend unavailable (offline xla stub — swap in \
             the real `xla` bindings in rust/Cargo.toml to execute kernels)"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// A host-side literal value (shape + f32 data). The stub keeps real
/// data so literal construction/reshape work; only *execution* fails.
#[derive(Clone, Debug, Default)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
}

impl Literal {
    pub fn vec1(v: &[f32]) -> Literal {
        Literal {
            data: v.to_vec(),
            dims: vec![v.len() as i64],
        }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.data.len() {
            return Err(Error(format!(
                "reshape: {} elements into shape {dims:?}",
                self.data.len()
            )));
        }
        Ok(Literal {
            data: self.data.clone(),
            dims: dims.to_vec(),
        })
    }

    pub fn to_tuple1(&self) -> Result<Literal> {
        Ok(self.clone())
    }

    pub fn to_vec<T: FromF32>(&self) -> Result<Vec<T>> {
        Ok(self.data.iter().map(|&x| T::from_f32(x)).collect())
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Element conversion for [`Literal::to_vec`] (the repo only reads f32).
pub trait FromF32 {
    fn from_f32(x: f32) -> Self;
}

impl FromF32 for f32 {
    fn from_f32(x: f32) -> Self {
        x
    }
}

#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::unavailable("HloModuleProto::from_text_file"))
    }
}

#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _inputs: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute"))
    }
}

#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_works_offline() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3, 7]).is_err());
    }

    #[test]
    fn execution_surface_reports_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x").is_err());
        let msg = format!("{}", PjRtClient::cpu().unwrap_err());
        assert!(msg.contains("unavailable"));
    }
}
