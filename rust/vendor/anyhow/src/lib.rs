//! Minimal offline shim of the `anyhow` API — exactly the surface this
//! repository uses (`Error`, `Result`, `anyhow!`, `bail!`, `ensure!`,
//! `Context`). String-backed: context wraps as `"context: cause"`, which
//! matches how the callers format errors (`{e}` / `{e:#}`).

use std::fmt;

/// A string-backed error value. Like the real `anyhow::Error`, it does
/// NOT implement `std::error::Error` itself (that is what allows the
/// blanket `From<E: std::error::Error>` conversion used by `?`).
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg<M: fmt::Display>(msg: M) -> Error {
        Error {
            msg: msg.to_string(),
        }
    }

    /// Wrap with context, innermost cause last.
    pub fn context<C: fmt::Display>(self, ctx: C) -> Error {
        Error {
            msg: format!("{ctx}: {}", self.msg),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to errors.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{ctx}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::result::Result<(), std::io::Error> {
        Err(std::io::Error::new(std::io::ErrorKind::Other, "boom"))
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            io_err()?;
            Ok(())
        }
        assert!(format!("{}", inner().unwrap_err()).contains("boom"));
    }

    #[test]
    fn context_wraps_outermost_first() {
        let e = io_err().context("reading file").unwrap_err();
        assert_eq!(format!("{e}"), "reading file: boom");
        let e = io_err().with_context(|| format!("task {}", 7)).unwrap_err();
        assert_eq!(format!("{e:#}"), "task 7: boom");
    }

    #[test]
    fn macros_build_errors() {
        let e: Error = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
        let s = String::from("owned");
        let e: Error = anyhow!(s);
        assert_eq!(e.to_string(), "owned");
        let e: Error = anyhow!("x={} y={}", 1, 2);
        assert_eq!(e.to_string(), "x=1 y=2");
        fn f() -> Result<()> {
            ensure!(1 + 1 == 3, "math broke: {}", 42);
            Ok(())
        }
        assert_eq!(f().unwrap_err().to_string(), "math broke: 42");
        fn g() -> Result<()> {
            ensure!(false);
            Ok(())
        }
        assert!(g().unwrap_err().to_string().contains("condition failed"));
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        assert!(v.context("missing").is_err());
    }
}
