//! End-to-end driver on a real workload trace shape: the Google-trace
//! macro benchmark (§5.3) across all four schedulers × both partitioners,
//! reporting the paper's headline metric — small/medium-job response-time
//! reduction of UWFQ-P vs UJF-P — plus the full Table 2 and Fig. 7 CSVs.
//!
//! ```bash
//! cargo run --release --example google_trace_sim [-- trace.csv]
//! ```
//! With a CSV argument (see `workload::tracefile`) it runs a real WTA
//! export instead of the shaped generator.

use uwfq::bench::{figures, tables};
use uwfq::config::Config;
use uwfq::sweep::Sweep;
use uwfq::workload::tracefile;

fn main() -> Result<(), String> {
    let base = Config::default(); // 32 cores, the paper's testbed scale
    let swp = Sweep::auto(); // grid cells across all host cores
    let arg = std::env::args().nth(1);
    let w = match arg {
        Some(path) => {
            println!("loading trace {path}");
            tracefile::load_csv_file(&path)?
        }
        None => figures::default_macro_workload(base.seed),
    };
    println!(
        "macro workload: {} jobs, {} users, {:.0} core-s over {:.0} s window \
         (theoretical utilization {:.2})\n",
        w.jobs.len(),
        w.users().len(),
        w.total_slot_time(),
        w.span_s(),
        w.utilization(base.cores, 500.0)
    );

    let t2 = tables::table2(&w, &base, &swp);
    println!("{}", tables::render_table2(&t2));

    let get = |label: &str| t2.rows.iter().find(|r| r.label == label).unwrap();
    let (uwfq_p, ujf_p) = (get("UWFQ-P"), get("UJF-P"));
    let small = 100.0 * (1.0 - uwfq_p.rt_0_80 / ujf_p.rt_0_80);
    let medium = 100.0 * (1.0 - uwfq_p.rt_80_95 / ujf_p.rt_80_95);
    let avg = 100.0 * (1.0 - uwfq_p.rt_avg / ujf_p.rt_avg);
    println!("headline (paper §5.3: small-job RT −74% / medium −52% / avg −38% for UWFQ-P vs UJF-P):");
    println!("  measured: small-job RT −{small:.0}%  medium −{medium:.0}%  avg −{avg:.0}%");

    std::fs::create_dir_all("out").map_err(|e| e.to_string())?;
    tables::write_table2_csv("out/table2_macro.csv", &t2).map_err(|e| e.to_string())?;
    let f7 = figures::fig7(&w, &base, &swp);
    figures::write_fig7_csv("out", &f7).map_err(|e| e.to_string())?;
    println!("\nwrote out/table2_macro.csv and out/fig7_user_violations.csv");
    Ok(())
}
