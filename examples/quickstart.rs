//! Quickstart: submit a few multi-user analytics jobs to the engine under
//! UWFQ and read the scheduling metrics — the 60-second tour of the
//! public API.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use uwfq::bench::{run_one, run_ujf_reference};
use uwfq::config::Config;
use uwfq::core::job::JobSpec;
use uwfq::metrics::fairness::{fairness_vs_ujf, DvrDenominator};
use uwfq::sched::PolicyKind;
use uwfq::workload::{UserClass, Workload};

fn main() {
    // 1. Configure the engine: 8 cores, UWFQ, default Spark partitioning.
    let cfg = Config::default()
        .with_cores(8)
        .with_policy(PolicyKind::Uwfq);

    // 2. Describe a workload: user 1 floods five 4-second jobs; user 2
    //    submits one small job shortly after. Each analytics job is a
    //    load → compute ×2 → collect stage chain (paper §5.2).
    let mut jobs: Vec<JobSpec> = (0..5)
        .map(|i| {
            JobSpec::three_phase(1, &format!("flood-{i}"), uwfq::s_to_us(0.1 * i as f64),
                32.0, 256 << 20, 16, None)
        })
        .collect();
    jobs.push(JobSpec::three_phase(2, "interactive", uwfq::s_to_us(1.0), 4.0, 64 << 20, 4, None));
    let workload = Workload {
        name: "quickstart".into(),
        jobs,
        user_class: [(1, UserClass::Frequent), (2, UserClass::Infrequent)]
            .into_iter()
            .collect(),
    };

    // 3. Run it through the discrete-event cluster and compare with the
    //    UJF fairness reference.
    let m = run_one(&cfg, &workload);
    let ujf = run_ujf_reference(&cfg, &workload);
    let fair = fairness_vs_ujf(&m, &ujf, DvrDenominator::GreaterThanZero);

    println!("engine: {} cores, policy {}", cfg.cores, m.label);
    println!("makespan {:.2} s, utilization {:.2}\n", m.makespan_s, m.utilization);
    println!("{:<14} {:>8} {:>10} {:>10}", "job", "user", "RT (s)", "slowdown");
    for o in &m.outcomes {
        println!("{:<14} {:>8} {:>10.2} {:>10.2}", o.name, o.user, o.rt, o.slowdown());
    }
    println!(
        "\nuser 2's interactive job overtakes the flood: RT {:.2} s vs {:.2} s avg for user 1",
        m.mean_rt_of_user(2),
        m.mean_rt_of_user(1),
    );
    println!(
        "fairness vs UJF: DVR {:.2} ({} violations), DSR {:.2} ({} slacks)",
        fair.dvr, fair.violations, fair.dsr, fair.slacks
    );
    assert!(m.mean_rt_of_user(2) < m.mean_rt_of_user(1));
}
