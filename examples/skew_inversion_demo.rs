//! Figures 3 & 4 live: shows (a) how one skewed partition stretches a
//! job's completion under default partitioning and how ATR partitioning
//! dilutes it, and (b) how a long low-priority job blocks a newly arrived
//! high-priority job (priority inversion) unless tasks are ATR-sized.
//!
//! Prints ASCII Gantt charts of executor cores over time.
//!
//! ```bash
//! cargo run --release --example skew_inversion_demo
//! ```

use uwfq::bench::figures;
use uwfq::config::Config;
use uwfq::sweep::Sweep;

fn gantt(spans: &[(usize, f64, f64)], width: usize) {
    let t_max = spans.iter().map(|s| s.2).fold(0.0, f64::max);
    let cores = spans.iter().map(|s| s.0).max().unwrap_or(0) + 1;
    for core in 0..cores {
        let mut line = vec![b'.'; width];
        for &(_c, s, e) in spans.iter().filter(|s| s.0 == core) {
            let a = ((s / t_max) * (width - 1) as f64) as usize;
            let b = (((e / t_max) * (width - 1) as f64) as usize).max(a);
            for cell in line.iter_mut().take(b + 1).skip(a) {
                *cell = b'#';
            }
        }
        println!("  core {core:>2} |{}| ", String::from_utf8_lossy(&line));
    }
    println!("          0{:>width$.1}s", t_max, width = width - 1);
}

fn main() {
    let base = Config::default().with_cores(8);

    println!("== Fig. 3 — task skew (one 5× hot partition) ==\n");
    let f3 = figures::fig3(&base, &Sweep::seq());
    for (label, rt, spans) in &f3.runs {
        println!("{label}: completion {rt:.2} s");
        gantt(spans, 64);
        println!();
    }
    let (d, r) = (f3.runs[0].1, f3.runs[1].1);
    println!("runtime partitioning cuts the skewed job's completion by {:.0}%\n", 100.0 * (1.0 - r / d));

    println!("== Fig. 4 — priority inversion ==\n");
    let f4 = figures::fig4(&base, &Sweep::seq());
    for (label, hi, lo) in &f4.runs {
        println!("{label}: high-priority job RT {hi:.2} s (low-priority job {lo:.2} s)");
    }
    let (d_hi, r_hi) = (f4.runs[0].1, f4.runs[1].1);
    println!(
        "\nwith ATR-sized tasks the high-priority job starts ~immediately: RT −{:.0}%",
        100.0 * (1.0 - r_hi / d_hi)
    );
}
