//! Multi-user serving on the REAL execution backend: executor-core
//! threads run the AOT-compiled Pallas analytics kernel via PJRT, the
//! UWFQ coordinator schedules stages, and every job returns real
//! [mean; variance] statistics over synthetic trip records.
//!
//! This is the three-layer proof: Rust coordinator (L3) → jax graph (L2)
//! → Pallas kernel (L1), with Python nowhere at runtime.
//!
//! ```bash
//! make artifacts && cargo run --release --example multi_user_serving
//! ```

use uwfq::config::Config;
use uwfq::exec::run_real;
use uwfq::runtime::ArtifactStore;
use uwfq::sched::PolicyKind;
use uwfq::workload::scenarios::micro_job;

fn main() -> anyhow::Result<()> {
    let dir = ArtifactStore::default_dir();
    anyhow::ensure!(
        dir.join("manifest.json").exists(),
        "artifacts missing — run `make artifacts` first"
    );

    let cfg = Config {
        cores: 4,
        policy: PolicyKind::Uwfq,
        ..Config::default()
    };

    // Scenario-1-in-miniature: user 1 is frequent (a burst of short
    // jobs), users 2 and 3 drop in with single tiny jobs mid-burst.
    let mut jobs = Vec::new();
    for i in 0..3 {
        jobs.push(micro_job(1, "short", 0.05 * i as f64, None));
    }
    jobs.push(micro_job(2, "tiny", 0.4, None));
    jobs.push(micro_job(3, "tiny", 0.8, None));

    println!(
        "spawning {} executor cores; {} jobs from 3 users; policy {}",
        cfg.cores,
        jobs.len(),
        cfg.policy.name()
    );
    let t0 = std::time::Instant::now();
    let report = run_real(cfg, jobs, &dir, 0.05)?;
    println!(
        "completed {} jobs in {:.2} s wall ({:.2} s engine makespan)\n",
        report.completed.len(),
        t0.elapsed().as_secs_f64(),
        report.makespan_s
    );

    println!("{:<8} {:>6} {:>9}   result (mean fare / var fare)", "job", "user", "RT (s)");
    let mut rows: Vec<_> = report.completed.iter().collect();
    rows.sort_by_key(|c| c.job);
    for c in rows {
        let out = &report.results[&c.job];
        // column 3 = base fare (normalized stats).
        println!(
            "{:<8} {:>6} {:>9.2}   {:+.4} / {:.4}",
            c.name, c.user, c.response_time(), out[3], out[8 + 3]
        );
    }

    // The infrequent users' tiny jobs must not be starved behind user 1's
    // burst: UWFQ gives them earlier virtual deadlines.
    let tiny_worst = report
        .completed
        .iter()
        .filter(|c| c.user != 1)
        .map(|c| c.response_time())
        .fold(0.0f64, f64::max);
    let short_worst = report
        .completed
        .iter()
        .filter(|c| c.user == 1)
        .map(|c| c.response_time())
        .fold(0.0f64, f64::max);
    println!(
        "\nworst tiny-job RT (infrequent users): {tiny_worst:.2} s; worst burst-job RT: {short_worst:.2} s"
    );
    for (k, (mean_s, n)) in &report.task_wall {
        println!("measured task wall time (k={k}): {:.1} ms over {n} tasks", mean_s * 1e3);
    }
    Ok(())
}
