"""L2 — the analytics-job compute graph, composed from the L1 kernel.

The paper's micro-benchmark analytics job has three phases (§5.2):

  1. *load* — read the partition and normalize it (per-block column
     standardization here; the file scan itself is the Rust data layer),
  2. *compute* — the dominant phase: k operations per row (the Pallas
     ``rowops`` kernel),
  3. *collect* — reduce per-task partials into the final statistics.

``compute_block`` (phases 1+2, per task) and ``aggregate`` (phase 3, driver
side) are the two computations AOT-lowered by ``aot.py``.  The op-count ``k``
is a *static* compile-time parameter — one HLO artifact per variant — because
HLO is shape/program-static; the Rust coordinator picks the variant matching
the job's requested op count.
"""

import jax.numpy as jnp

from .kernels import rowops as rk

# Padded fan-in of the AOT aggregate computation.  The Rust collect stage
# zero-pads (partials, counts) up to this many entries per call and chains
# calls for larger fan-ins.
AGG_FANIN = 32

# Op-count variants to AOT-compile.  Must stay in sync with the Rust
# ArtifactStore / workload specs.
VARIANTS = (1, 4, 16, 64)


def normalize(x):
    """Load-stage transform: per-block column standardization."""
    x = x.astype(jnp.float32)
    mean = jnp.mean(x, axis=0, keepdims=True)
    std = jnp.std(x, axis=0, keepdims=True)
    return (x - mean) / (std + 1e-6)


def compute_block(x, k: int, tile: int = rk.TILE):
    """Phases 1+2 for one (ROWS, COLS) partition block.

    Returns a 1-tuple of f32[(2, cols)] partial [sum; sumsq] statistics.
    """
    return (rk.rowops(normalize(x), k, tile=tile),)


def aggregate(partials, counts):
    """Phase 3: fold up to AGG_FANIN per-task partials into [mean; var].

    Zero-padded rows (counts == 0) contribute nothing; callers guarantee
    ``sum(counts) > 0``.
    """
    total = jnp.sum(counts)
    s = jnp.sum(partials[:, 0, :], axis=0)
    ss = jnp.sum(partials[:, 1, :], axis=0)
    mean = s / total
    var = ss / total - mean * mean
    return (jnp.stack([mean, var]),)
