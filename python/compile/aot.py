"""AOT entry point: lower the L2 computations to HLO *text* artifacts.

Interchange format is HLO text, NOT serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids that the xla crate's xla_extension
0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Python runs exactly once (``make artifacts``); the Rust binary is
self-contained afterwards.  A ``manifest.json`` describes the emitted
artifacts so the Rust ``ArtifactStore`` never hardcodes shapes.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import rowops as rk


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def emit(out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    block = jax.ShapeDtypeStruct((rk.ROWS, rk.COLS), jnp.float32)
    manifest = {
        "block_rows": rk.ROWS,
        "cols": rk.COLS,
        "tile": rk.TILE,
        "agg_fanin": model.AGG_FANIN,
        "compute": [],
    }

    for k in model.VARIANTS:
        lowered = jax.jit(lambda x, k=k: model.compute_block(x, k)).lower(block)
        name = f"compute_k{k}.hlo.txt"
        path = os.path.join(out_dir, name)
        with open(path, "w") as f:
            f.write(to_hlo_text(lowered))
        manifest["compute"].append({"k": k, "file": name})
        print(f"wrote {path}")

    partials = jax.ShapeDtypeStruct((model.AGG_FANIN, 2, rk.COLS), jnp.float32)
    counts = jax.ShapeDtypeStruct((model.AGG_FANIN,), jnp.float32)
    lowered = jax.jit(model.aggregate).lower(partials, counts)
    agg_name = "aggregate.hlo.txt"
    with open(os.path.join(out_dir, agg_name), "w") as f:
        f.write(to_hlo_text(lowered))
    manifest["aggregate"] = {"file": agg_name}
    print(f"wrote {os.path.join(out_dir, agg_name)}")

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {os.path.join(out_dir, 'manifest.json')}")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description="AOT-lower L2 computations to HLO text")
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    args = ap.parse_args()
    # --out may be a file path (legacy Makefile style) or a directory.
    out = args.out
    if out.endswith(".hlo.txt"):
        out = os.path.dirname(out)
    emit(out)


if __name__ == "__main__":
    main()
