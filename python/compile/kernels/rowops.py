"""L1 — Pallas kernel: the per-partition analytics hot-spot.

The paper's micro-benchmark jobs "apply a varying number of operations per
row" of a columnar trip-record dataset (NYC TLC FHVHV).  This kernel is that
computation phase for one data partition: a fused per-row nonlinear op-chain
(`k` rounds of ``tanh(y * C1 + C0)``) followed by a columnar partial
aggregation (per-column sum and sum-of-squares), which the collect stage
(Rust side / ``model.aggregate``) later reduces into global statistics.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the (ROWS, COLS) block is
tiled into (TILE, COLS) row tiles via ``BlockSpec`` — each tile is the
VMEM-resident working set (512x8 f32 = 16 KiB), the op-chain runs on the VPU
lanes, and the aggregation is a two-stage tree (in-tile ``sum`` then
cross-tile accumulation into the output ref).  One HBM read per element, one
O(COLS) write — the schedule a CUDA version would express with threadblocks
is expressed here with the grid + BlockSpec.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and interpret mode lowers to plain HLO that the Rust runtime
(xla crate, PJRT CPU client) executes directly.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default geometry — must match rust/src/data (BLOCK_ROWS/COLS) and the AOT
# manifest.  Changing these requires `make artifacts`.
ROWS = 4096
COLS = 8
TILE = 512

# Per-column affine constants of the op-chain.  Arbitrary but fixed: they
# only need to make the chain non-foldable and column-dependent.
def _chain_consts(cols: int):
    c = jnp.arange(cols, dtype=jnp.float32)
    c1 = 0.75 + 0.05 * c        # slope per column
    c0 = 0.01 * (c - cols / 2)  # bias per column
    return c1, c0


def _rowops_kernel(x_ref, o_ref, *, k: int, cols: int):
    """Pallas kernel body for one (TILE, COLS) row tile.

    Accumulates partial [sum; sumsq] for its tile into ``o_ref`` (shape
    (2, COLS)), which is shared across grid steps.
    """
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    y = x_ref[...]
    c1, c0 = _chain_consts(cols)
    for _ in range(k):  # k is static per compiled variant → fully fused chain
        y = jnp.tanh(y * c1 + c0)

    # In-tile reduction (stage 1 of the aggregation tree).
    tile_sum = jnp.sum(y, axis=0)
    tile_sumsq = jnp.sum(y * y, axis=0)
    # Cross-tile accumulation (stage 2).
    o_ref[...] += jnp.stack([tile_sum, tile_sumsq])


def rowops(x, k: int, tile: int = TILE):
    """Apply the k-op chain + partial aggregation to block ``x``.

    Args:
      x: f32[(rows, cols)] with ``rows % tile == 0``.
      k: static op-chain length (the paper's "operations per row").
      tile: row-tile size (VMEM working-set knob).

    Returns:
      f32[(2, cols)] — per-column [sum; sum-of-squares] of the transformed
      block.
    """
    rows, cols = x.shape
    if rows % tile != 0:
        raise ValueError(f"rows={rows} not a multiple of tile={tile}")
    grid = (rows // tile,)
    return pl.pallas_call(
        partial(_rowops_kernel, k=k, cols=cols),
        out_shape=jax.ShapeDtypeStruct((2, cols), jnp.float32),
        grid=grid,
        in_specs=[pl.BlockSpec((tile, cols), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((2, cols), lambda i: (0, 0)),
        interpret=True,
    )(x)
