"""Pure-jnp correctness oracle for the Pallas ``rowops`` kernel.

This is the ground truth the kernel is validated against at build time
(pytest + hypothesis); it contains no Pallas, no tiling — just the math.
"""

import jax.numpy as jnp


def chain_consts(cols: int):
    """Per-column affine constants — must match rowops._chain_consts."""
    c = jnp.arange(cols, dtype=jnp.float32)
    return 0.75 + 0.05 * c, 0.01 * (c - cols / 2)


def rowops_ref(x, k: int):
    """Reference: k-round tanh op-chain then per-column [sum; sumsq]."""
    c1, c0 = chain_consts(x.shape[1])
    y = x.astype(jnp.float32)
    for _ in range(k):
        y = jnp.tanh(y * c1 + c0)
    return jnp.stack([jnp.sum(y, axis=0), jnp.sum(y * y, axis=0)])


def normalize_ref(x):
    """Reference for the load-stage per-block column normalization."""
    x = x.astype(jnp.float32)
    mean = jnp.mean(x, axis=0, keepdims=True)
    std = jnp.std(x, axis=0, keepdims=True)
    return (x - mean) / (std + 1e-6)


def aggregate_ref(partials, counts):
    """Reference for the collect-stage reduction.

    Args:
      partials: f32[(n, 2, cols)] — per-task [sum; sumsq] partials
        (zero-padded entries must have counts == 0).
      counts: f32[(n,)] — row counts per task.

    Returns:
      f32[(2, cols)] — [mean; variance] over all rows.
    """
    total = jnp.sum(counts)
    s = jnp.sum(partials[:, 0, :], axis=0)
    ss = jnp.sum(partials[:, 1, :], axis=0)
    mean = s / total
    var = ss / total - mean * mean
    return jnp.stack([mean, var])
