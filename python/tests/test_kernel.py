"""Kernel vs ref: THE core correctness signal for the L1 Pallas kernel.

Deterministic parametrized checks plus hypothesis sweeps over block
geometry, op-count, and value ranges.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels import rowops as rk


def _rand(rows, cols, seed=0, scale=1.0):
    key = jax.random.PRNGKey(seed)
    return jax.random.normal(key, (rows, cols), dtype=jnp.float32) * scale


@pytest.mark.parametrize("k", [0, 1, 4, 16, 64])
def test_rowops_matches_ref_default_geometry(k):
    x = _rand(rk.ROWS, rk.COLS, seed=k)
    got = rk.rowops(x, k)
    want = ref.rowops_ref(x, k)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("tile", [64, 128, 512, 1024, 4096])
def test_rowops_tile_invariance(tile):
    """Partial aggregation must be independent of the tiling schedule."""
    x = _rand(4096, 8, seed=7)
    got = rk.rowops(x, 4, tile=tile)
    want = ref.rowops_ref(x, 4)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


def test_rowops_rejects_non_multiple_tile():
    x = _rand(100, 8)
    with pytest.raises(ValueError):
        rk.rowops(x, 1, tile=64)


def test_rowops_k0_is_pure_aggregation():
    x = _rand(512, 8, seed=3)
    got = rk.rowops(x, 0, tile=256)
    np.testing.assert_allclose(got[0], jnp.sum(x, axis=0), rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(got[1], jnp.sum(x * x, axis=0), rtol=1e-5, atol=1e-4)


def test_rowops_sumsq_nonnegative():
    x = _rand(1024, 8, seed=11, scale=10.0)
    got = rk.rowops(x, 2, tile=256)
    assert bool(jnp.all(got[1] >= 0))


def test_rowops_tanh_bounds():
    """After >=1 chain round every value is in (-1,1): sums bounded by rows."""
    x = _rand(1024, 8, seed=5, scale=100.0)
    got = rk.rowops(x, 1, tile=512)
    assert bool(jnp.all(jnp.abs(got[0]) <= 1024.0))
    assert bool(jnp.all(got[1] <= 1024.0))


@settings(max_examples=25, deadline=None)
@given(
    tiles=st.integers(min_value=1, max_value=8),
    tile=st.sampled_from([64, 128, 256]),
    cols=st.sampled_from([1, 2, 4, 8, 16]),
    k=st.integers(min_value=0, max_value=8),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    scale=st.sampled_from([0.1, 1.0, 10.0]),
)
def test_rowops_hypothesis_sweep(tiles, tile, cols, k, seed, scale):
    rows = tiles * tile
    x = _rand(rows, cols, seed=seed, scale=scale)
    got = rk.rowops(x, k, tile=tile)
    want = ref.rowops_ref(x, k)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_rowops_special_values_finite(seed):
    """Zeros and constant blocks produce finite outputs."""
    x = jnp.zeros((256, 8), dtype=jnp.float32)
    got = rk.rowops(x, 3, tile=128)
    assert bool(jnp.all(jnp.isfinite(got)))
    x = jnp.full((256, 8), float(seed % 97) - 48.0, dtype=jnp.float32)
    got = rk.rowops(x, 3, tile=128)
    assert bool(jnp.all(jnp.isfinite(got)))
