"""AOT pipeline tests: artifacts are valid HLO text + manifest is coherent."""

import json
import os

from compile import aot, model
from compile.kernels import rowops as rk


def test_emit_roundtrip(tmp_path):
    out = str(tmp_path / "artifacts")
    manifest = aot.emit(out)

    assert manifest["block_rows"] == rk.ROWS
    assert manifest["cols"] == rk.COLS
    assert manifest["agg_fanin"] == model.AGG_FANIN
    assert [v["k"] for v in manifest["compute"]] == list(model.VARIANTS)

    # Files exist, are HLO text, and declare the right entry layouts.
    for v in manifest["compute"]:
        text = open(os.path.join(out, v["file"])).read()
        assert "HloModule" in text and "ENTRY" in text
        assert f"f32[{rk.ROWS},{rk.COLS}]" in text
    agg = open(os.path.join(out, manifest["aggregate"]["file"])).read()
    assert f"f32[{model.AGG_FANIN},2,{rk.COLS}]" in agg

    # manifest.json round-trips.
    loaded = json.load(open(os.path.join(out, "manifest.json")))
    assert loaded == manifest


def test_artifacts_are_pure_hlo_no_custom_calls(tmp_path):
    """interpret=True must lower pallas to plain HLO (no Mosaic custom-call),
    otherwise the Rust CPU PJRT client cannot execute the artifact."""
    out = str(tmp_path / "a")
    manifest = aot.emit(out)
    for v in manifest["compute"]:
        text = open(os.path.join(out, v["file"])).read()
        assert "custom-call" not in text, v["file"]
