"""L2 model tests: shapes, normalization, aggregation semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref
from compile.kernels import rowops as rk


def _rand(rows, cols, seed=0, scale=1.0):
    key = jax.random.PRNGKey(seed)
    return jax.random.normal(key, (rows, cols), dtype=jnp.float32) * scale


def test_compute_block_shape_and_value():
    x = _rand(rk.ROWS, rk.COLS, seed=1)
    (out,) = model.compute_block(x, 4)
    assert out.shape == (2, rk.COLS)
    want = ref.rowops_ref(ref.normalize_ref(x), 4)
    np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-4)


def test_normalize_zero_mean_unit_std():
    x = _rand(2048, 8, seed=2, scale=5.0) + 3.0
    xn = model.normalize(x)
    np.testing.assert_allclose(jnp.mean(xn, axis=0), 0.0, atol=1e-4)
    np.testing.assert_allclose(jnp.std(xn, axis=0), 1.0, atol=1e-3)


def test_normalize_constant_column_no_nan():
    x = jnp.ones((512, 8), dtype=jnp.float32)
    xn = model.normalize(x)
    assert bool(jnp.all(jnp.isfinite(xn)))


def test_aggregate_matches_direct_stats():
    """Aggregating per-task partials == stats over the concatenated rows."""
    rows, cols, ntasks = 512, 8, 5
    blocks = [_rand(rows, cols, seed=i) for i in range(ntasks)]
    partials = jnp.stack(
        [jnp.stack([b.sum(0), (b * b).sum(0)]) for b in blocks]
    )
    pad = model.AGG_FANIN - ntasks
    partials = jnp.concatenate(
        [partials, jnp.zeros((pad, 2, cols), jnp.float32)]
    )
    counts = jnp.array([rows] * ntasks + [0] * pad, dtype=jnp.float32)
    (out,) = model.aggregate(partials, counts)
    allrows = jnp.concatenate(blocks)
    np.testing.assert_allclose(out[0], jnp.mean(allrows, axis=0), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(
        out[1], jnp.var(allrows, axis=0), rtol=1e-3, atol=1e-4
    )


def test_aggregate_padding_is_inert():
    """Zero-padded entries must not change the result."""
    cols = rk.COLS
    p = jnp.abs(_rand(3, cols, seed=9)).reshape(3, 1, cols)
    partials3 = jnp.concatenate([p, p * p], axis=1)  # (3,2,cols)
    counts3 = jnp.array([100.0, 200.0, 300.0])

    def padded(n):
        pp = jnp.concatenate(
            [partials3, jnp.zeros((n - 3, 2, cols), jnp.float32)]
        )
        cc = jnp.concatenate([counts3, jnp.zeros((n - 3,), jnp.float32)])
        # re-pad to AGG_FANIN for the fixed-shape entry point
        pp = jnp.concatenate(
            [pp, jnp.zeros((model.AGG_FANIN - n, 2, cols), jnp.float32)]
        )
        cc = jnp.concatenate([cc, jnp.zeros((model.AGG_FANIN - n,), jnp.float32)])
        return model.aggregate(pp, cc)[0]

    np.testing.assert_allclose(padded(3), padded(10), rtol=1e-6)
    np.testing.assert_allclose(padded(3), padded(model.AGG_FANIN), rtol=1e-6)


@settings(max_examples=15, deadline=None)
@given(
    ntasks=st.integers(min_value=1, max_value=model.AGG_FANIN),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_aggregate_hypothesis_variance_nonnegative(ntasks, seed):
    rows, cols = 256, rk.COLS
    blocks = [_rand(rows, cols, seed=seed + i, scale=3.0) for i in range(ntasks)]
    partials = jnp.stack([jnp.stack([b.sum(0), (b * b).sum(0)]) for b in blocks])
    pad = model.AGG_FANIN - ntasks
    partials = jnp.concatenate([partials, jnp.zeros((pad, 2, cols), jnp.float32)])
    counts = jnp.array([rows] * ntasks + [0] * pad, dtype=jnp.float32)
    (out,) = model.aggregate(partials, counts)
    assert bool(jnp.all(out[1] >= -1e-3))
    assert bool(jnp.all(jnp.isfinite(out)))


def test_variants_cover_workload_opcounts():
    """Rust workloads request k in VARIANTS; keep the contract explicit."""
    assert model.VARIANTS == (1, 4, 16, 64)
    assert rk.ROWS % rk.TILE == 0
